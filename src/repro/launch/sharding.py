"""Sharding rules: parameter, optimizer, activation and cache layouts.

Scheme (MaxText-style FSDP + TP, adapted per architecture):
  * FSDP axes = ("pod","data") — every large matrix shards one dim over
    FSDP (ZeRO-3; XLA all-gathers per layer inside the scan) and one over
    "model" (Megatron TP). Optimizer moments share the param specs
    (ZeRO-1 falls out for free).
  * EP: MoE expert banks (E, d, ff) shard E over "model".
  * Activations: batch over DP axes; head/ff internals over "model"
    (applied via with_sharding_constraint inside the blocks).
  * Caches: batch over DP; KV heads over "model" when divisible, else
    the sequence dim shards over "model" (split-K decode — the MLA
    latent-cache case).

Every rule passes through :func:`fit_spec`, which drops an axis from the
spec when the dimension is not divisible by the mesh axis size (e.g.
glm4's 2 KV heads cannot split 16-way; xlstm's 4 heads likewise). This
keeps all 10 architectures compiling on the same mesh — replication is
the correct degenerate case, and the roofline shows its cost honestly.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes as mesh_dp_axes


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fit_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose axis size does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
        elif dim % _axis_size(mesh, ax) == 0 and dim > 0:
            out.append(ax)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules: ordered (regex over path, spec-builder) pairs
# --------------------------------------------------------------------------


def _param_rules(fsdp, tp):
    """Spec templates for the *unstacked* (per-layer) param shapes."""
    return [
        # embeddings / unembedding
        (r"embed$",                P(fsdp, tp)),
        (r"lm_head$",              P(fsdp, tp)),
        # attention (GQA)
        (r"attn/wq$",              P(fsdp, tp)),
        (r"attn/wk$",              P(fsdp, tp)),
        (r"attn/wv$",              P(fsdp, tp)),
        (r"attn/wo$",              P(tp, fsdp)),
        # MLA
        (r"attn/w_dkv$",           P(fsdp, None)),
        (r"attn/w_kr$",            P(fsdp, None)),
        (r"attn/w_dq$",            P(fsdp, None)),
        (r"attn/w_uq$",            P(None, tp)),
        (r"attn/w_uk$",            P(None, tp)),
        (r"attn/w_uv$",            P(None, tp)),
        # MoE (EP over tp axis; shared expert like a dense MLP)
        (r"moe/router$",           P(fsdp, None)),
        (r"moe/w_gate$",           P(tp, fsdp, None)),
        (r"moe/w_up$",             P(tp, fsdp, None)),
        (r"moe/w_down$",           P(tp, None, fsdp)),
        (r"moe/shared/w_gate$",    P(fsdp, tp)),
        (r"moe/shared/w_up$",      P(fsdp, tp)),
        (r"moe/shared/w_down$",    P(tp, fsdp)),
        # dense MLP (also arctic dense-residual, zamba shared block)
        (r"(mlp|dense)/w_gate$",   P(fsdp, tp)),
        (r"(mlp|dense)/w_up$",     P(fsdp, tp)),
        (r"(mlp|dense)/w_down$",   P(tp, fsdp)),
        # mamba
        (r"mamba/in_proj$",        P(fsdp, tp)),
        (r"mamba/out_proj$",       P(tp, fsdp)),
        (r"mamba/conv_w$",         P(None, tp)),
        (r"mamba/conv_b$",         P(tp)),
        (r"mamba/norm$",           P(tp)),
        # xlstm mLSTM
        (r"blk/w_z$",              P(fsdp, tp)),
        (r"blk/w_u$",              P(fsdp, tp)),
        (r"blk/w_q$",              P(None, tp)),
        (r"blk/w_k$",              P(None, tp)),
        (r"blk/w_v$",              P(None, tp)),
        (r"blk/w_if$",             P(fsdp, None)),
        (r"blk/w_down$",           P(tp, fsdp)),
        (r"blk/conv_w$",           P(None, tp)),
        (r"blk/conv_b$",           P(tp)),
        (r"blk/(skip|out_norm)$",  P(tp)),
        # xlstm sLSTM
        (r"blk/w_ifzo$",           P(fsdp, tp)),
        (r"blk/r_ifzo$",           P(None, None, tp)),
        (r"blk/ffn_gate$",         P(fsdp, tp)),
        (r"blk/ffn_up$",           P(fsdp, tp)),
        (r"blk/ffn_down$",         P(tp, fsdp)),
    ]


_STACKED = re.compile(r"^(layers|mlstm_layers|slstm_layers)/")


def param_specs(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching an (eval_shape) params pytree."""
    fsdp = mesh_dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    rules = _param_rules(fsdp, tp)

    def spec_for(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        stacked = bool(_STACKED.match(key))
        shape = leaf.shape
        core_shape = shape[1:] if stacked else shape
        for pat, spec in rules:
            if re.search(pat, key):
                fitted = fit_spec(core_shape, spec, mesh)
                if stacked:
                    return P(None, *fitted)
                return fitted
        # default: replicate (norm scales, biases, gates, small vectors)
        return P()

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        params_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in paths_leaves])


# --------------------------------------------------------------------------
# batch / activation / cache rules
# --------------------------------------------------------------------------


def stage_activation_spec(mesh: Mesh, rows: int) -> P:
    """Spec for the (B, S, D) hidden stream crossing a stage boundary.

    Batch over the DP axes (when divisible); replicated over "pipe" —
    every stage sees the full stream and ``steps._pipe_send`` moves it
    between stages in program order — and over "model" (the blocks
    apply their own internal constraints).
    """
    dp = mesh_dp_axes(mesh)
    bspec = dp if rows % _axis_size(mesh, dp) == 0 else None
    return P(bspec, None, None)


def stage_param_specs(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                      layers_per_stage) -> Any:
    """Per-stage spec pytrees for capacity-sized layer slices.

    Stage s owns ``layers_per_stage[s]`` contiguous layers: its stacked
    leaves have that leading dim but the same core shapes, and the
    stacked-leaf spec puts None on the leading dim — so the per-stage
    specs are identical across every stage plan (params never shard
    over "pipe"). That invariance is what makes a checkpoint saved
    under one stage partition restore into another resharding-free and
    bit-exactly. Computed honestly through fit_spec on the sliced
    shapes rather than asserted.
    """
    def sliced(n):
        def f(path, leaf):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if _STACKED.match(key):
                return jax.ShapeDtypeStruct(
                    (int(n),) + tuple(leaf.shape[1:]),
                    getattr(leaf, "dtype", jnp.float32))
            return leaf
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
            params_shape)
        return jax.tree_util.tree_unflatten(
            treedef, [f(p, l) for p, l in paths_leaves])

    return [param_specs(cfg, sliced(n), mesh) for n in layers_per_stage]


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_rows: int,
                stub: Optional[bool] = None) -> Dict[str, P]:
    """Specs for the packed train batch {"inputs","labels","weights"}."""
    dp = mesh_dp_axes(mesh)
    bspec = dp if global_rows % _axis_size(mesh, dp) == 0 else None
    stub = cfg.frontend != "token" if stub is None else stub
    return {
        "inputs": P(bspec, None, None) if stub else P(bspec, None),
        "labels": P(bspec, None),
        "weights": P(bspec, None),
    }


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh,
                batch: int) -> Any:
    """Specs for the decode cache pytree (leading L/group dim = None)."""
    dp = mesh_dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    bspec = dp if batch % _axis_size(mesh, dp) == 0 else None

    def spec_for(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        shape = leaf.shape
        if re.search(r"(^|/)(k|v|attn_k|attn_v)$", key):
            # (L, B, S, Hkv, Dh): heads over tp when divisible, else seq
            if cfg.num_kv_heads % _axis_size(mesh, tp or "model") == 0 \
                    if tp else False:
                return fit_spec(shape, P(None, bspec, None, tp, None), mesh)
            return fit_spec(shape, P(None, bspec, tp, None, None), mesh)
        if re.search(r"c_kv$|k_rope$", key):
            # MLA latent (L, B, S, r): split-K — sequence over tp
            return fit_spec(shape, P(None, bspec, tp, None), mesh)
        if re.search(r"conv$", key):
            return fit_spec(shape, P(None, bspec, None, tp), mesh)
        if re.search(r"ssm$", key):
            # (L, B, H, P, N): heads over tp
            return fit_spec(shape, P(None, bspec, tp, None, None), mesh)
        if key.startswith("mlstm") or key.startswith("slstm"):
            return fit_spec(shape, P(None, bspec), mesh)
        return fit_spec(shape, P(None, bspec), mesh)

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in paths_leaves])


def paged_cache_specs(cfg: ModelConfig, cache_shape: Any,
                      mesh: Mesh) -> Any:
    """Specs for the paged KV pool pytree.

    Pool leaves are (L, N, bs, ...) — there is no batch dim, and the
    block dims (N, bs) stay replicated so block tables index the same
    physical slot on every rank. Only the feature dims shard: GQA KV
    heads (or the MLA latent rank / rope dim) over "model" when
    divisible.
    """
    tp = "model" if "model" in mesh.axis_names else None

    def spec_for(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        shape = leaf.shape
        if re.search(r"(^|/)(k|v)$", key):
            # (L, N, bs, Hkv, Dh): heads over tp when divisible
            return fit_spec(shape, P(None, None, None, tp, None), mesh)
        if re.search(r"c_kv$|k_rope$", key):
            # (L, N, bs, r) / (L, N, bs, Dr): latent dim over tp
            return fit_spec(shape, P(None, None, None, tp), mesh)
        return fit_spec(shape, P(None, None), mesh)

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in paths_leaves])


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
