"""Mesh construction (single-pod and multi-pod production meshes).

Defined as functions — importing this module never touches jax device
state, so test processes keep their 1-device world unless they opt in.

Production target: TPU v5e pods of 256 chips. Single-pod mesh is
(data=16, model=16); multi-pod adds a leading "pod" axis (2, 16, 16)
whose collectives ride DCN — that is the slow/heterogeneous link where
the HetSeq capacity planner and the compressed hierarchical reduction
earn their keep.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 1) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pipe > 1:
        shape = (pipe,) + shape
        axes = ("pipe",) + axes
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pipe: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    if pipe > 1:
        return jax.make_mesh((pipe, data, model), ("pipe", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ("pod","data") when pod exists.

    Never includes "pipe" — pipeline stages replicate params/batch over
    the pipe axis and exchange only stage-boundary activations, so DP
    collectives (grad reduction, weighting sums) must not span it.
    """
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def pipe_axis(mesh: Mesh) -> Optional[str]:
    return "pipe" if "pipe" in mesh.axis_names else None


def pipe_size(mesh: Mesh) -> int:
    return mesh.shape["pipe"] if "pipe" in mesh.axis_names else 1


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
