"""Batched serving driver: prefill + decode with a static batch.

Serves a model with the production shardings: prompts are prefilled as
one batch, then tokens decode step-by-step against the KV cache. On the
CPU container this runs smoke configs; on TPU pods the same code serves
the full configs (the decode step is the ``decode_32k``/``long_500k``
dry-run cell).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.configs import base as cfgbase
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_mod
from repro.launch.mesh import dp_axes
from repro.models.model import build_model


def serve(args):
    cfg = (cfgbase.smoke_config(args.arch) if args.smoke
           else cfgbase.resolve(args.arch))
    model = build_model(cfg)
    dshape = tuple(int(x) for x in args.devices.split(","))
    axes = ("data", "model") if len(dshape) == 2 else ("pod", "data",
                                                       "model")
    mesh = jax.make_mesh(dshape, axes)
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")

    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(args.seed))
    with compat.set_mesh(mesh):
        prefill = steps_mod.build_prefill_step(model, shape, mesh)
        decode = steps_mod.build_decode_step(model, shape, mesh)

        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = dp_axes(mesh)
        bspec = dp if args.batch % np.prod(
            [mesh.shape[a] for a in dp]) == 0 else None
        rng = np.random.default_rng(args.seed)
        if cfg.frontend == "token":
            prompts = jax.device_put(
                jnp.asarray(rng.integers(0, cfg.vocab_size,
                                         (args.batch, max_len)), jnp.int32),
                NamedSharding(mesh, P(bspec, None)))
            tok_sharding = NamedSharding(mesh, P(bspec))
        else:
            prompts = jax.device_put(
                jnp.asarray(rng.standard_normal(
                    (args.batch, max_len, cfg.d_model)), jnp.bfloat16),
                NamedSharding(mesh, P(bspec, None, None)))
            tok_sharding = NamedSharding(mesh, P(bspec, None))

        t0 = time.time()
        # build_prefill_step pads the returned cache to the serving
        # length (shape.seq_len = prompt + gen), so decode continues
        # directly from the real prompt context
        logits, cache = prefill(params, prompts[:, :args.prompt_len]
                                if cfg.frontend == "token"
                                else prompts[:, :args.prompt_len, :])
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        def next_tok(lg):
            if cfg.frontend == "token":
                return jax.device_put(
                    jnp.argmax(lg, axis=-1).astype(jnp.int32),
                    tok_sharding)
            return jax.device_put(
                jnp.zeros((args.batch, cfg.d_model), jnp.bfloat16),
                tok_sharding)

        tok = next_tok(logits)
        generated = [np.asarray(jnp.argmax(logits, axis=-1))]
        t0 = time.time()
        for i in range(args.gen):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode(params, tok, cache, pos)
            tok = next_tok(logits)
            generated.append(np.asarray(jnp.argmax(logits, axis=-1)))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    toks_out = np.stack(generated, axis=1)
    tput = args.batch * args.gen / max(t_decode, 1e-9)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len}"
          f" gen={args.gen}")
    print(f"[serve] prefill {t_prefill * 1e3:.1f} ms, decode "
          f"{t_decode * 1e3:.1f} ms total ({tput:.1f} tok/s)")
    print(f"[serve] sample tokens[0]: {toks_out[0][:12].tolist()}")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": tput}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--devices", default="1,1")
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
