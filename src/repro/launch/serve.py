"""Continuous-batching serving driver on the heterogeneous mesh.

Replaces the old static-batch demo: requests arrive open-loop, are
routed across pods by capacity score (slow pods hold proportionally
fewer concurrent sequences), prefilled in length buckets into a paged
KV cache, and decoded one token per step at per-sequence depths —
finished sequences release their blocks immediately and new arrivals
take their slots mid-flight. See docs/architecture.md §serving engine.

Sharding note: the decode-slot batch and the prefill batch shard over
the DP axes ONLY when divisible by the DP extent; otherwise the step
builders fall back to fully-replicated batches and warn loudly (every
rank computes the whole batch — a real throughput loss, not a
cosmetic detail). Pick ``--slots``/``--prefill-batch`` as multiples of
prod(devices[:-1]).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --slots 4 --requests 12 --pod-speeds 1,0.5
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import base as cfgbase
from repro.configs.base import ShapeConfig
from repro.launch import sharding as shr
from repro.launch import steps as steps_mod
from repro.launch.mesh import dp_size
from repro.models.kvcache import PagedLayout
from repro.models.model import Model, build_model
from repro.serve import (CapacityRouter, EngineConfig, Request, Scheduler,
                         ServeEngine)


def build_engine(model: Model, params, mesh, layout: PagedLayout,
                 slots: int, prefill_batch: int,
                 pod_speeds: Sequence[float],
                 bucket_lens: Optional[Sequence[int]] = None
                 ) -> ServeEngine:
    """Wire scheduler + jitted paged steps into a ServeEngine.

    Compiles one decode step (fixed (slots,) shapes, cache donated) and
    one prefill step per length bucket (fixed (prefill_batch, bucket)
    shapes, cache donated). Call — and run the engine — inside
    ``compat.set_mesh(mesh)``.
    """
    router = CapacityRouter(slots, pod_speeds)
    sched = Scheduler(layout, router, slots, bucket_lens)
    decode = steps_mod.build_paged_decode_step(model, mesh, layout, slots)
    prefill_fns = {
        b: functools.partial(
            steps_mod.build_paged_prefill_step(model, mesh, layout, b,
                                               prefill_batch),
            params)
        for b in sched.bucket_lens}
    cache_shape = jax.eval_shape(
        functools.partial(model.init_paged_cache, layout))
    cspecs = shr.paged_cache_specs(model.cfg, cache_shape, mesh)
    init_cache_fn = jax.jit(
        functools.partial(model.init_paged_cache, layout),
        out_shardings=shr.named(mesh, cspecs))
    return ServeEngine(EngineConfig(decode_slots=slots,
                                    prefill_batch=prefill_batch,
                                    attention_impl=model.cfg.attention_impl),
                       layout, sched, functools.partial(decode, params),
                       prefill_fns, init_cache_fn)


def synthetic_requests(n: int, vocab: int, rate: float,
                       prompt_lens: Tuple[int, int],
                       gen_lens: Tuple[int, int], seed: int
                       ) -> List[Request]:
    """Open-loop Poisson arrivals with mixed prompt/gen lengths."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for rid in range(n):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        glen = int(rng.integers(gen_lens[0], gen_lens[1] + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab, plen))
        reqs.append(Request(rid=rid, prompt=prompt,
                            max_new_tokens=glen, arrival=t))
    return reqs


def static_generate(model: Model, params, mesh, prompts: np.ndarray,
                    gen: int) -> np.ndarray:
    """Static-batch reference path (the pre-engine serving loop): one
    shared prompt length, every sequence decodes ``gen`` tokens in
    lock-step. Kept as the bit-identity baseline for the paged path
    (benchmarks/serve_bench.py) and as the non-paged comparison point.
    Returns (B, gen) generated token ids."""
    batch, prompt_len = prompts.shape
    shape = ShapeConfig("serve-static", prompt_len + gen, batch, "decode")
    prefill = steps_mod.build_prefill_step(model, shape, mesh)
    decode = steps_mod.build_decode_step(model, shape, mesh)
    logits, cache = prefill(params, jnp.asarray(prompts, jnp.int32))
    out = [np.argmax(np.asarray(logits), axis=-1)]
    tok = jnp.asarray(out[-1], jnp.int32)
    for i in range(gen - 1):
        pos = jnp.int32(prompt_len + i)
        logits, cache = decode(params, tok, cache, pos)
        out.append(np.argmax(np.asarray(logits), axis=-1))
        tok = jnp.asarray(out[-1], jnp.int32)
    return np.stack(out, axis=1)


def serve(args):
    cfg = (cfgbase.smoke_config(args.arch) if args.smoke
           else cfgbase.resolve(args.arch))
    if cfg.frontend != "token":
        raise SystemExit(f"--arch {args.arch}: the serving engine "
                         f"requires a token frontend")
    cfg = dataclasses.replace(cfg, attention_impl=args.attention_impl)
    model = build_model(cfg)
    dshape = tuple(int(x) for x in args.devices.split(","))
    axes = ("data", "model") if len(dshape) == 2 else ("pod", "data",
                                                       "model")
    mesh = jax.make_mesh(dshape, axes)
    pod_speeds = ([float(s) for s in args.pod_speeds.split(",")]
                  if args.pod_speeds else [1.0] * dp_size(mesh))

    max_seq = args.max_prompt + args.max_gen
    mbs = -(-max_seq // args.block_size)
    num_blocks = args.num_blocks or args.slots * mbs
    layout = PagedLayout(block_size=args.block_size,
                         num_blocks=num_blocks, max_blocks_per_seq=mbs)

    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(args.seed))
    reqs = synthetic_requests(
        args.requests, cfg.vocab_size, args.rate,
        (args.min_prompt, args.max_prompt), (args.min_gen, args.max_gen),
        args.seed)

    with compat.set_mesh(mesh):
        engine = build_engine(model, params, mesh, layout, args.slots,
                              args.prefill_batch, pod_speeds)
        result = engine.run(reqs)

    s = result.stats
    print(f"[serve] {cfg.name}: {s['requests']} requests, "
          f"{s['total_tokens']} tokens, pods {pod_speeds} "
          f"limits {s['pod_limits']}")
    print(f"[serve] modeled {s['modeled_tokens_per_sec']:.2f} tok/unit "
          f"(p50 {s['p50_time_per_token']:.3f} / "
          f"p99 {s['p99_time_per_token']:.3f} per token, "
          f"ttft {s['mean_ttft']:.3f})")
    print(f"[serve] {s['decode_steps']} decode steps, "
          f"{s['prefill_groups']} prefill groups, "
          f"{s['preemptions']} preemptions, block util "
          f"mean {s['block_util_mean']:.2f} peak {s['block_util_peak']:.2f},"
          f" wall {s['wall_seconds']:.1f}s")
    rid0 = min(result.tokens)
    print(f"[serve] sample tokens[{rid0}]: "
          f"{result.tokens[rid0][:12]}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", default="1,1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (concurrent sequences)")
    ap.add_argument("--prefill-batch", type=int, default=2)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size (0 = slots x max blocks/seq)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="open-loop arrival rate (requests per unit)")
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--min-gen", type=int, default=4)
    ap.add_argument("--max-gen", type=int, default=32)
    ap.add_argument("--pod-speeds", default="",
                    help="comma list of modeled pod speeds "
                         "(default: 1.0 per DP rank)")
    ap.add_argument("--attention-impl", default="reference",
                    choices=list(cfgbase.ATTENTION_IMPLS),
                    help="decode attention kernels: 'pallas' gathers KV "
                         "blocks through the block table inside the "
                         "kernel (interpret-mode fallback, loudly, off "
                         "TPU); 'reference' materializes the window")
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
