import os
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count=512")

# NOTE: the two lines above MUST precede every other import — jax locks
# the device count at first init. No `from __future__` here for the same
# reason (it would have to be line 1).

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**input_specs).compile()`` must succeed
on the single-pod (16,16) mesh AND the 2-pod (2,16,16) mesh for every
assigned architecture x input shape. Failures here (sharding mismatch,
OOM at compile, unsupported collective) are bugs in the system.

Artifacts per cell (written to --out):
  <cell>.json   memory_analysis + cost_analysis + collective stats
  <cell>.hlo    optimized HLO text (optional, --save-hlo)

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out artifacts/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat


def _cell_id(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               het_mode: str = "allreduce", compression: str = "none",
               accum: int = 1):
    """Build and lower one cell. Returns (lowered, meta)."""
    from repro.configs import base
    from repro.configs.base import HetConfig, OptimizerConfig, TrainConfig
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import build_model

    cfg = base.resolve(arch)
    shape = base.SHAPES[shape_name]
    ok, why = base.shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": True, "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    if shape.kind == "train":
        if accum == 1:
            accum = base.accum_for(cfg, multi_pod)
        elif accum <= 0:
            accum = 1
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": mesh.size, "kind": shape.kind,
        "params": model.cfg.param_count(),
        "params_active": model.cfg.active_param_count(),
        "het_mode": het_mode, "compression": compression,
        "accum": accum if shape.kind == "train" else 1,
    }
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            tcfg = TrainConfig(
                model=cfg, shape=shape,
                het=HetConfig(grad_reduction=het_mode,
                              compression=compression, accum_steps=accum),
                optimizer=base.optimizer_for(cfg))
            step = steps.build_train_step(model, tcfg, mesh)
            state_sh = steps.state_shapes(model, tcfg, mesh)
            batch_sh = steps.input_specs(cfg, shape, model, "train")
            lowered = step.lower(state_sh, batch_sh)
        elif shape.kind == "prefill":
            step = steps.build_prefill_step(model, shape, mesh)
            params_sh = jax.eval_shape(model.init_params,
                                       jax.random.PRNGKey(0))
            ins = steps.input_specs(cfg, shape, model, "prefill")
            lowered = step.lower(params_sh, ins["inputs"])
        else:  # decode
            step = steps.build_decode_step(model, shape, mesh)
            params_sh = jax.eval_shape(model.init_params,
                                       jax.random.PRNGKey(0))
            ins = steps.input_specs(cfg, shape, model, "decode")
            lowered = step.lower(params_sh, ins["tokens"], ins["cache"],
                                 ins["pos"])
    return lowered, meta


def analyze(lowered, meta: Dict[str, Any], pod_size: int = 256
            ) -> Dict[str, Any]:
    from repro.roofline import hlo as hlo_mod
    from repro.roofline.report import model_flops_for

    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    meta["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_device_bytes": int(ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes
                                 + ma.output_size_in_bytes
                                 - ma.alias_size_in_bytes),
    }
    meta["fits_16gb_cpu_measured"] = \
        meta["memory"]["peak_device_bytes"] < 16e9
    # TPU-true estimate: exact state + temp/2 (undo the CPU backend's
    # bf16->f32 GEMM-operand legalization, documented in EXPERIMENTS.md)
    meta["memory"]["tpu_estimate_bytes"] = int(
        ma.argument_size_in_bytes + ma.temp_size_in_bytes / 2)
    meta["fits_16gb"] = meta["memory"]["tpu_estimate_bytes"] < 16e9

    ca = compiled.cost_analysis()
    chips = meta["chips"]

    hlo_text = compiled.as_text()
    # XLA's cost_analysis counts while bodies ONCE — the layer scan would
    # under-report by ~num_layers x. program_costs() rebuilds trip-count-
    # weighted FLOPs/bytes from the HLO call graph (roofline/hlo.py).
    pc = hlo_mod.program_costs(hlo_text)
    meta["cost"] = {
        "per_device_flops": pc.flops,
        "per_device_bytes": pc.hbm_bytes,
        "hlo_flops": pc.flops * chips,
        "hlo_bytes": pc.hbm_bytes * chips,
        "xla_unweighted_flops": float(ca.get("flops", 0.0)),
        "xla_unweighted_bytes": float(ca.get("bytes accessed", 0.0)),
        "dot_count": pc.dot_count,
    }
    stats = hlo_mod.collective_stats(hlo_text, pod_size=pod_size)
    meta["collectives"] = {
        "ici_bytes": stats.ici_bytes, "dcn_bytes": stats.dcn_bytes,
        "count": stats.count, "by_type": stats.bytes_by_type,
    }

    from repro.configs import base as cfgbase
    shape = cfgbase.SHAPES[meta["shape"]]
    tokens = (shape.tokens if meta["kind"] != "decode"
              else shape.global_batch)    # decode: 1 new token per seq
    meta["model_flops"] = model_flops_for(meta["params_active"], tokens,
                                          meta["kind"])
    return meta, hlo_text


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, het_mode: str = "allreduce",
             compression: str = "none", accum: int = 1) -> Dict[str, Any]:
    mesh_kind = "multi" if multi_pod else "single"
    cell = _cell_id(arch, shape_name, mesh_kind)
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod,
                                   het_mode=het_mode,
                                   compression=compression, accum=accum)
        if lowered is None:
            meta.update({"arch": arch, "shape": shape_name,
                         "mesh": mesh_kind, "status": "skipped"})
            print(f"[dryrun] {cell}: SKIP ({meta['reason']})")
        else:
            meta, hlo_text = analyze(lowered, meta)
            meta["status"] = "ok"
            mem_gb = meta["memory"]["peak_device_bytes"] / 1e9
            tpu_gb = meta["memory"]["tpu_estimate_bytes"] / 1e9
            print(f"[dryrun] {cell}: OK compile={meta['compile_s']}s "
                  f"mem/dev={mem_gb:.2f}GB (tpu~{tpu_gb:.2f}GB) "
                  f"fits={meta['fits_16gb']} "
                  f"flops/dev={meta['cost']['per_device_flops']:.3e}")
            if save_hlo:
                with open(os.path.join(out_dir, cell + ".hlo"), "w") as fh:
                    fh.write(hlo_text)
    except Exception as e:  # a failed cell is a bug — record it loudly
        meta = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc()}
        print(f"[dryrun] {cell}: ERROR {e!r}")
    meta["wall_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as fh:
        json.dump(meta, fh, indent=1, default=str)
    return meta


def main() -> int:
    from repro.configs import base

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--het-mode", default="allreduce",
                    choices=["allreduce", "hierarchical"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--accum", type=int, default=1,
                    help="override gradient-accumulation (1 = per-arch policy)")
    args = ap.parse_args()

    archs = base.list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = (list(base.SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                results.append(run_cell(
                    arch, shape, mesh_kind == "multi", args.out,
                    save_hlo=args.save_hlo, het_mode=args.het_mode,
                    compression=args.compression, accum=args.accum))
    bad = [r for r in results if r.get("status") == "error"]
    ok = [r for r in results if r.get("status") == "ok"]
    skipped = [r for r in results if r.get("status") == "skipped"]
    print(f"\n[dryrun] {len(ok)} ok, {len(skipped)} skipped, "
          f"{len(bad)} failed")
    for r in bad:
        print(f"  FAILED: {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"{r['error']}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
