"""Step builders: jitted train / prefill / decode with explicit shardings.

``build_train_step`` assembles the full HetSeq step:
  1. weighted objective over the packed (dummy-padded) global batch —
     per-token weights make heterogeneous capacity exact (core M1/M3);
  2. optional gradient accumulation scan (core M4, shared scan core in
     core/accumulate.py);
  3. gradient reduction, selected by ``HetConfig.grad_reduction`` and
     ``HetConfig.bucket_mb``:
       * "allreduce"    — paper-faithful: XLA's automatic reduction from
         the shardings (FSDP => reduce-scatter + all-gather);
       * "bucketed_allreduce" — explicit flat-buffer reduction: grads
         are packed into fixed-size f32 buckets (core/buckets.py) and
         reduced with ONE psum_scatter + ONE all_gather over the whole
         DP axis set, instead of XLA's per-leaf collectives;
       * "hierarchical" — beyond-paper: params replicated over "pod",
         FSDP over "data"; in-pod reduction stays automatic (ICI), the
         cross-pod leg is an explicit shard_map(axis_names={"pod"})
         collective, optionally int8-compressed with error feedback.
         With ``bucket_mb > 0`` the cross-pod leg runs the bucketed
         engine: two collectives per step total, error feedback held
         in ONE flat (pods, num_buckets, bucket_elems) array; with
         ``bucket_mb == 0`` the legacy per-leaf walk (one quantize +
         one gather per leaf) is kept for comparison;
  4. AdamW update (optimizer state sharded like params = ZeRO-1).

``HetConfig.overlap="buckets"`` (both explicit reduction modes)
replaces steps 3+4 with the fused double-buffered pipeline: the
per-bucket exchange (core/buckets.py::exchange_buckets_overlapped)
overlaps bucket k+1's quantize/pack with bucket k's in-flight
collective, and the flat-view optimizer update
(optim/adam.py::apply_update_flat) for bucket k is applied the moment
its reduced payload lands — the optimizer moments then live packed as
one (num_buckets, bucket_elems) array in TrainState, replicated over
the reduction axes. In the backward-overlap flush pipeline LAMB
streams too: its moment updates and per-leaf norm partials land per
bucket, with only the trust-ratio application deferred to one trailing
elementwise pass (optim/lamb.py; the after-backward bucket engine
keeps LAMB's whole-stack barrier — see the rationale there).
Global-norm clipping keeps the pipelined exchange but updates behind a
barrier (the clip factor needs every bucket before the first moment
update).

``HetConfig.pipeline_stages > 1`` adds the pipe dimension: the uniform
layer stack is cut into contiguous capacity-sized stages
(core/pipeline.py StagePlan) and the accumulation microbatches stream
through them in 1F1B program order — per-stage VJP segments exchanged
through send/recv regions, grads reduced per-stage through the bucket
engine when ``grad_reduction="bucketed_allreduce"``
(_build_pipeline_step).

``input_specs`` provides ShapeDtypeStruct stand-ins for every cell of
the (architecture x shape) grid — the dry-run lowers against these, no
allocation ever happens.
"""
from __future__ import annotations

import functools
import logging
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (ModelConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import accumulate as acc
from repro.core import buckets as bkt
from repro.core import pipeline as pipe
from repro.core import weighting
from repro.launch import sharding as shr
from repro.launch.mesh import dp_axes as mesh_dp_axes, dp_size, tp_axis
from repro.models.blocks import ParallelCtx
from repro.models.model import Model
from repro.optim import adam, lamb, schedules

logger = logging.getLogger(__name__)

# quantization block size for the compressed cross-pod exchanges
_BLOCK = 256


def make_parallel_ctx(mesh: Optional[Mesh]) -> ParallelCtx:
    if mesh is None:
        return ParallelCtx()
    return ParallelCtx(mesh=mesh, dp_axes=mesh_dp_axes(mesh),
                       tp_axis=tp_axis(mesh))


# --------------------------------------------------------------------------
# train state
# --------------------------------------------------------------------------


class TrainState(NamedTuple):
    params: Any
    opt: adam.AdamState
    err: Any                       # error-feedback state or () when unused
    # bucketed reduction: ONE flat (pods, num_buckets, bucket_elems) f32
    # array; legacy per-leaf reduction: a (pods, *leaf) pytree mirror
    # overlap="buckets": opt.m / opt.v are packed
    # (num_buckets, bucket_elems) arrays (core/buckets.py layout),
    # replicated over the reduction axes, NOT pytree mirrors


def _err_enabled(tcfg: TrainConfig, mesh: Mesh) -> bool:
    return (tcfg.het.grad_reduction == "hierarchical"
            and tcfg.het.compression != "none"
            and tcfg.het.error_feedback
            and "pod" in mesh.axis_names)


def _overlap_enabled(tcfg: TrainConfig, mesh: Mesh) -> bool:
    """Whether this config runs a fused per-bucket pipeline
    (``overlap`` in {"buckets", "backward"}).

    Overlap is a schedule of the bucketed engine, so it needs an
    explicit reduction mode with a bucket layout to pipeline over
    (``HetConfig.validate`` raises on misconfiguration); a mesh with
    no reduction axes silently falls back to the non-overlap path.
    """
    tcfg.het.validate()
    if tcfg.het.overlap == "none":
        return False
    if not _reduce_axes(tcfg, mesh):
        return False               # no reduction axes on this mesh
    return True


def validate_train_config(model: Model, tcfg: TrainConfig,
                          mesh: Mesh) -> None:
    """Full config validation at ``build_train_step`` time.

    Mesh-independent rules live in ``HetConfig.validate``; this adds
    the mesh/model-dependent rules so misconfigurations raise one
    clear ``ValueError`` up front instead of failing deep in the
    pipeline. Also used by ``launch/train.py --dry-run``.
    """
    from repro.models import transformer as tr

    het = tcfg.het.validate()
    if not 0.0 <= tcfg.label_smoothing < 1.0:
        raise ValueError(
            f"TrainConfig.label_smoothing must be in [0, 1), got "
            f"{tcfg.label_smoothing}")
    if het.grad_reduction == "bucketed_allreduce" \
            and not mesh_dp_axes(mesh):
        raise ValueError(
            "grad_reduction='bucketed_allreduce' needs a mesh with "
            f"data-parallel axes; got {mesh.axis_names}")
    if het.overlap == "backward":
        # model rules checked UNCONDITIONALLY: a mesh with no reduction
        # axes falls back to the non-overlap schedule, but an
        # unsupported stack plan used to ride that fallback silently and
        # then blow up the moment the same config met a real mesh —
        # supports_staged_backward drives a loud build-time error either
        # way (tests/test_overlap.py regression)
        if not tr.supports_staged_backward(model.cfg):
            raise ValueError(
                "HetConfig.overlap='backward' stages the backward over "
                "the uniform block stack (dense | moe | mla); stack "
                f"plan '{tr.stack_plan(model.cfg)}' of "
                f"'{model.cfg.name}' is not supported — use "
                "overlap='buckets'")
        if model.cfg.scan_layers:
            raise ValueError(
                "HetConfig.overlap='backward' needs ModelConfig."
                "scan_layers=False: the staged layer-by-layer backward "
                "is an unrolled program, and bit-exactness with the "
                "monolithic path requires the monolithic stack "
                "unrolled too (launch/train.py: --no-scan-layers)")
    if het.pipeline_stages > 1:
        if not tr.supports_staged_backward(model.cfg):
            raise ValueError(
                "HetConfig.pipeline_stages > 1 cuts the uniform block "
                "stack (dense | moe | mla) into contiguous stages; "
                f"stack plan '{tr.stack_plan(model.cfg)}' of "
                f"'{model.cfg.name}' is not supported")
        if model.cfg.scan_layers:
            raise ValueError(
                "HetConfig.pipeline_stages > 1 needs ModelConfig."
                "scan_layers=False: the per-stage VJP segments are an "
                "unrolled program, and bit-exactness with pure DP "
                "requires the monolithic stack unrolled too "
                "(launch/train.py: --no-scan-layers)")
        if model.cfg.num_layers < het.pipeline_stages:
            raise ValueError(
                f"pipeline_stages={het.pipeline_stages} exceeds the "
                f"{model.cfg.num_layers}-layer stack of "
                f"'{model.cfg.name}' (every stage needs >= 1 layer)")
        if "pipe" in mesh.axis_names \
                and mesh.shape["pipe"] != het.pipeline_stages:
            raise ValueError(
                f"mesh 'pipe' axis has size {mesh.shape['pipe']} but "
                f"HetConfig.pipeline_stages={het.pipeline_stages} — "
                "build the mesh with pipe=pipeline_stages "
                "(launch/mesh.py)")


def _flat_barrier_update(pb, red, m, v, lr_step, ocfg, lr, *, inv_w,
                         dmask, segs, n_leaves):
    """Whole-stack flat optimizer update behind the barrier.

    Shared by the after-backward ("buckets") and backward-overlap
    pipelines for configs whose statistics need every reduced bucket
    BEFORE the first moment update (global-norm clipping), and by the
    after-backward engine for ALL of LAMB (the backward-overlap flush
    pipeline streams LAMB instead — optim/lamb.py has the full
    exactness rationale). Returns
    (new_pb, new_m, new_v, gnorm, mean trust ratio).
    """
    gsc = red * inv_w
    gnorm = jnp.sqrt(jnp.sum(gsc * gsc))
    cs = (jnp.minimum(1.0, ocfg.grad_clip /
                      jnp.maximum(gnorm, 1e-9))
          if ocfg.grad_clip > 0 else None)
    if ocfg.name == "lamb":
        new_pb, new_m, new_v, trust = lamb.apply_update_flat(
            pb, gsc, m, v, lr_step, ocfg, lr,
            decay_mask=dmask, seg_ids=segs,
            num_leaves=n_leaves, clip_scale=cs)
    else:
        new_pb, new_m, new_v = adam.apply_update_flat(
            pb, gsc, m, v, lr_step, ocfg, lr,
            decay_mask=dmask, clip_scale=cs)
        trust = jnp.ones((), jnp.float32)
    return new_pb, new_m, new_v, gnorm, trust


def _reduce_axes(tcfg: TrainConfig, mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes the explicit bucketed reduction runs over."""
    if tcfg.het.grad_reduction == "bucketed_allreduce":
        return mesh_dp_axes(mesh)
    return ("pod",) if "pod" in mesh.axis_names else ()


def stage_plan_for(model: Model,
                   tcfg: TrainConfig) -> Optional[pipe.StagePlan]:
    """The pipeline StagePlan for this config cell (None when off).

    When ``HetConfig.capacities`` has exactly ``pipeline_stages``
    positive entries they double as the per-stage speed scores — the
    same weight table the DP batch planner uses sizes the layer cut
    (core/pipeline.py). Anything else (empty / per-DP-rank-shaped /
    containing zeros, which mark dead DP ranks but cannot mark a
    pipeline stage) gets the uniform cut.
    """
    S = tcfg.het.pipeline_stages
    if S <= 1:
        return None
    caps = tcfg.het.capacities
    if len(caps) == S and all(c > 0 for c in caps):
        return pipe.plan_stages(model.cfg.num_layers, caps)
    return pipe.uniform_stages(model.cfg.num_layers, S)


def bucket_layout(model: Model, tcfg: TrainConfig,
                  mesh: Mesh) -> Optional[bkt.BucketLayout]:
    """The gradient bucket grid for this (model, config, mesh) cell.

    The bucket size is rounded so every bucket divides into per-rank
    shards of whole quantization blocks (ranks * _BLOCK).
    """
    if tcfg.het.bucket_mb <= 0:
        return None
    axes = _reduce_axes(tcfg, mesh)
    if not axes:
        return None
    ranks = 1
    for a in axes:
        ranks *= mesh.shape[a]
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    return bkt.build_layout(params_shape, bucket_mb=tcfg.het.bucket_mb,
                            multiple_of=ranks * _BLOCK)


def checkpoint_format(model: Model, tcfg: TrainConfig, mesh: Mesh) -> Dict:
    """The checkpoint ``"format"`` meta block for this config cell.

    Records how this cell lays TrainState out on disk: which fields are
    saved packed (``overlap="buckets"`` stores the optimizer moments as
    one (num_buckets, bucket_elems) stack) and the versioned
    ``BucketLayout`` record + fingerprint describing that grid, so a
    restore into ANY other cell can translate through the flat stream
    (checkpoint/repack.py) instead of failing on shape mismatch.
    ``hosts`` is the v3 per-host shard count (one writer per pod — the
    fleet unit that owns its own disk); the layout record carries the
    matching bucket-row extents each host writes.
    """
    from repro.checkpoint import repack

    hosts = int(mesh.shape["pod"]) if "pod" in mesh.axis_names else 1
    fmt: Dict[str, Any] = {"version": repack.FORMAT_VERSION,
                           "state": "pytree", "packed_fields": [],
                           "layout": None,
                           "hosts": hosts,
                           # which HetConfig.overlap mode wrote this
                           # checkpoint — restore logs (never silently
                           # adapts) when the restore target differs
                           "overlap": tcfg.het.overlap,
                           # stage partition that wrote this checkpoint
                           # (core/pipeline.py stage_record, or None
                           # without pipelining). Params are stored
                           # per-leaf, so a checkpoint restores
                           # bit-exactly under ANY stage plan — the
                           # record exists so restore can LOG the plan
                           # change, and repack.py can validate it
                           "pipeline": None}
    splan = stage_plan_for(model, tcfg)
    if splan is not None:
        fmt["pipeline"] = pipe.stage_record(splan)
    if _overlap_enabled(tcfg, mesh):
        lo = bucket_layout(model, tcfg, mesh)
        params_shape = jax.eval_shape(model.init_params,
                                      jax.random.PRNGKey(0))
        paths = [repack.path_key(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(params_shape)[0]]
        rec = bkt.layout_record(lo, leaf_paths=paths, hosts=hosts)
        fmt.update(state="packed",
                   packed_fields=["opt/m", "opt/v"],
                   layout=rec,
                   fingerprint=rec["fingerprint"])
    return fmt


def state_shapes(model: Model, tcfg: TrainConfig, mesh: Mesh):
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    if _overlap_enabled(tcfg, mesh):
        # fused per-bucket pipeline: moments live packed in the flat
        # bucket layout (NOTE: layout depends on the mesh's reduction
        # ranks — re-meshing an overlap checkpoint needs a repack)
        lo = bucket_layout(model, tcfg, mesh)
        opt_shape = jax.eval_shape(functools.partial(
            adam.init_state_flat, lo.num_buckets, lo.bucket_elems,
            tcfg.optimizer))
    else:
        opt_shape = jax.eval_shape(
            functools.partial(adam.init_state, cfg=tcfg.optimizer),
            params_shape)
    if _err_enabled(tcfg, mesh):
        pods = mesh.shape["pod"]
        layout = bucket_layout(model, tcfg, mesh)
        if layout is not None:
            err_shape: Any = jax.ShapeDtypeStruct(
                layout.error_shape(pods), jnp.float32)
        else:
            err_shape = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct((pods,) + p.shape,
                                               jnp.float32),
                params_shape)
    else:
        err_shape = ()
    return TrainState(params=params_shape, opt=opt_shape, err=err_shape)


def _strip_axes(spec: P, drop: Tuple[str, ...]) -> P:
    """Remove the given mesh axes from a PartitionSpec (replicate)."""
    out = []
    for ax in spec:
        if isinstance(ax, tuple):
            kept = tuple(a for a in ax if a not in drop)
            out.append(kept if kept else None)
        else:
            out.append(None if ax in drop else ax)
    return P(*out)


def state_specs(model: Model, tcfg: TrainConfig, mesh: Mesh) -> TrainState:
    shapes = state_shapes(model, tcfg, mesh)
    hier = (tcfg.het.grad_reduction == "hierarchical"
            and "pod" in mesh.axis_names)
    bucketed_ar = tcfg.het.grad_reduction == "bucketed_allreduce"
    pspecs = shr.param_specs(model.cfg, shapes.params, mesh)
    if hier or bucketed_ar:
        # explicit-reduction modes: params replicated across the manual
        # reduction axes so the gradient leg is ours to schedule
        # (hierarchical keeps FSDP over "data"; bucketed_allreduce
        # replicates over the whole DP set)
        drop = ("pod",) if hier else _reduce_axes(tcfg, mesh)
        pspecs = jax.tree.map(lambda s: _strip_axes(s, drop), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        # token-embedding gathers with a sharded vocab dim hit an XLA
        # SPMD-partitioner bug inside partially-manual regions; shard the
        # table on d_model only (gather pass-through dim) in this mode
        if isinstance(pspecs, dict) and "embed" in pspecs:
            tp = "model" if "model" in mesh.axis_names else None
            vshape = shapes.params["embed"].shape
            pspecs = dict(pspecs)
            pspecs["embed"] = shr.fit_spec(vshape, P(None, tp), mesh)
    if _overlap_enabled(tcfg, mesh):
        # packed moments: replicated over the reduction axes (the flat
        # stack mixes every leaf's sharding — the ZeRO-1 mirror does
        # not apply; documented trade in ROADMAP.md)
        ospecs = adam.AdamState(step=P(), m=P(), v=P())
    else:
        ospecs = adam.AdamState(step=P(), m=pspecs, v=pspecs)
    if shapes.err == ():
        especs: Any = ()
    elif isinstance(shapes.err, jax.ShapeDtypeStruct):
        especs = P("pod")              # flat bucketed error state
    else:
        especs = jax.tree.map(lambda s: P("pod", *s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    return TrainState(params=pspecs, opt=ospecs, err=especs)


def init_train_state(model: Model, tcfg: TrainConfig, mesh: Mesh,
                     key) -> TrainState:
    """Initialize on-device with the right shardings (M8: same init
    everywhere — a single global RNG key IS the broadcast)."""
    specs = state_specs(model, tcfg, mesh)
    shapes = state_shapes(model, tcfg, mesh)

    def init(k):
        params = model.init_params(k)
        if _overlap_enabled(tcfg, mesh):
            lo = bucket_layout(model, tcfg, mesh)
            opt = adam.init_state_flat(lo.num_buckets, lo.bucket_elems,
                                       tcfg.optimizer)
        else:
            opt = adam.init_state(params, tcfg.optimizer)
        if shapes.err == ():
            err: Any = ()
        else:
            err = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), shapes.err)
        return TrainState(params=params, opt=opt, err=err)

    with compat.set_mesh(mesh):
        return jax.jit(init, out_shardings=shr.named(mesh, specs))(key)


def init_params_sharded(model: Model, mesh: Mesh, key):
    """Initialize bare params with the production shardings (serving)."""
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(model.cfg, params_shape, mesh)
    with compat.set_mesh(mesh):
        return jax.jit(model.init_params,
                       out_shardings=shr.named(mesh, pspecs))(key)


def init_cache_sharded(model: Model, shape: ShapeConfig, mesh: Mesh):
    """Zero cache with the decode-step shardings."""
    b = shape.global_batch
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(model.cfg, cache_shape, mesh, b)
    with compat.set_mesh(mesh):
        return jax.jit(functools.partial(model.init_cache, b,
                                         shape.seq_len),
                       out_shardings=shr.named(mesh, cspecs))()


# --------------------------------------------------------------------------
# gradient reduction modes
# --------------------------------------------------------------------------


def _quant_lastdim(x: jnp.ndarray, block: int):
    """Blockwise int8 quantization along the LAST dim only.

    Unlike the flatten-everything kernel wrapper, this preserves the
    sharding of every other dim — flattening a (data, model)-sharded
    matrix forces XLA to all-gather it before the reshape (measured:
    38 GB of replicated gradient copies in the hier step).
    """
    last = x.shape[-1]
    bs = min(block, last)
    x = compat.pad_trailing(x, (-last) % bs)
    nb = x.shape[-1] // bs
    blocks = x.reshape(*x.shape[:-1], nb, bs)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0], last


def _dequant_lastdim(q: jnp.ndarray, scale: jnp.ndarray, last: int):
    deq = q.astype(jnp.float32) * scale[..., None]
    deq = deq.reshape(*deq.shape[:-2], -1)
    return deq[..., :last]


def _cross_pod_reduce(grads: Any, err: Any, compress: str, pods: int,
                      block_size: int = _BLOCK) -> Tuple[Any, Any]:
    """LEGACY per-leaf walk, inside shard_map(manual={"pod"}).

    One collective per pytree leaf (compressed: one quantize + one
    full-payload gather per leaf — O(pods) receive bandwidth). Kept as
    the comparison baseline for the bucketed engine and for
    ``bucket_mb == 0`` configs; benchmarks/reduce_bench.py measures the
    difference.

    grads: this pod's gradient contribution (auto-sharded over data).
    err:   (1, *shape) this pod's persistent error-feedback state.
    """
    def leaf(g, e):
        if compress == "none":
            return jax.lax.psum(g, "pod"), e
        gf = g.astype(jnp.float32)
        if gf.ndim == 1:
            gf = gf[None]
            squeeze = True
        else:
            squeeze = False
        corrected = gf + (e.reshape(gf.shape).astype(jnp.float32)
                          if e is not None else 0.0)
        q, s, last = _quant_lastdim(corrected, block_size)
        deq_local = _dequant_lastdim(q, s, last)
        new_e = ((corrected - deq_local).reshape(e.shape)
                 if e is not None else e)
        # int8 payload + per-block scales are what cross the DCN link;
        # gathered along a NEW leading pod axis (all shardings preserved)
        q_all = compat.manual_all_gather(q, "pod", pods)
        s_all = compat.manual_all_gather(s, "pod", pods)
        deq = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None],
                      axis=0)
        out = deq.reshape(*deq.shape[:-2], -1)[..., :last]
        if squeeze:
            out = out[0]
        return out.astype(g.dtype), new_e

    if err == ():
        outs = jax.tree.map(lambda g: leaf(g, None)[0], grads)
        return outs, ()
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


def _reduce_bucketed(
    grads: Any,
    err: Any,
    *,
    axis,
    axis_size: int,
    compress: str,
    layout: bkt.BucketLayout,
    impl: str = "reference",
    block_size: int = _BLOCK,
) -> Tuple[Any, Any]:
    """THE bucketed-reduction entry point, inside shard_map(manual).

    Shared by both explicit modes — ``axis="pod"`` for the cross-pod
    leg of "hierarchical", ``axis=<dp axes>`` for "bucketed_allreduce".
    Packs the whole gradient pytree into the fixed-size bucket stack,
    runs the monolithic two-collective exchange, and unpacks. ``err``
    is this rank's (1, num_buckets, bucket_elems) slice of the flat
    error state, or None when error feedback is off. The overlap mode
    does NOT go through here — its fused reduce+optimizer pipeline
    never materializes the unpacked gradient tree (see
    build_train_step's overlap branch).
    """
    flat = bkt.pack_buckets(grads, layout)
    e = (err.reshape(layout.num_buckets, layout.bucket_elems)
         if err is not None else None)
    red, new_e = bkt.exchange_buckets(
        flat, e, axis=axis, axis_size=axis_size,
        compress=(compress != "none"), block_size=block_size,
        impl=impl, total=layout.total)
    out = bkt.unpack_buckets(red, layout)
    if new_e is None:
        return out, None
    return out, new_e.reshape(1, layout.num_buckets, layout.bucket_elems)


# --------------------------------------------------------------------------
# backward-overlap step (HetConfig.overlap="backward")
# --------------------------------------------------------------------------


def _path_top(entry) -> str:
    """Top-level key of a tree_flatten_with_path path entry."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def _staged_leaf_pieces(params_shape: Any, cfg: ModelConfig):
    """Per-leaf ``(offset_within_leaf, n, backward_stage)`` pieces.

    The model's layer partition mapped onto the flat stream: stacked
    ``layers`` leaves split into per-layer slices landing back to
    front (layer *l* at stage ``L - l``), the head leaves at stage 0,
    the embedding table last (stage ``L + 1`` — a tied table also
    receives a head-stage contribution, so its grad is only final at
    the end). Feeds ``core/buckets.py::bucket_readiness``.
    """
    from repro.models import transformer as tr

    L = cfg.num_layers
    head_keys = set(tr.head_param_keys(cfg))
    pieces = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            params_shape)[0]:
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        top = _path_top(path[0])
        if top == "layers":
            if n % L:
                raise ValueError(
                    f"stacked leaf {jax.tree_util.keystr(path)} of "
                    f"{n} elements does not split into {L} layers")
            per = n // L
            pieces.append([(l * per, per, L - l) for l in range(L)])
        elif top == "embed":
            pieces.append([(0, n, L + 1)])
        elif top in head_keys:
            pieces.append([(0, n, 0)])
        else:
            raise ValueError(
                f"overlap='backward': unexpected param subtree "
                f"'{top}' (uniform stack expects embed / final_norm / "
                f"lm_head / layers)")
    return pieces


def _build_backward_overlap_step(model: Model, tcfg: TrainConfig,
                                 mesh: Mesh, *, layout: bkt.BucketLayout,
                                 hier: bool, compress: str,
                                 use_err: bool, fused_stream: bool):
    """The ``overlap="backward"`` train step: flush gradient buckets
    DURING backprop instead of after it.

    Structure (identical on current jax and the old-jaxlib compat
    stack): the batch is reshaped rank-major and every backward stage
    is a vmapped per-layer VJP in plain SPMD at the TOP level of the
    jitted program (models/transformer.py staged segments — requires
    ``scan_layers=False`` so the monolithic comparison path compiles
    the same unrolled dots), while each bucket's two-collective
    exchange runs in its own small shard_map(manual) region, issued
    the moment the bucket's last contributing stage lands
    (core/buckets.py::BucketFlushPipeline, readiness derived from the
    layer partition). The program-order interleaving of exchange
    regions with the remaining backward stages is what hands the
    runtime the overlap; the CPU host mesh executes collectives
    eagerly, so the modeled bwd+link timeline in
    benchmarks/overlap_bench.py is the claim — exactly as for
    ``overlap="buckets"``.

    Exactness: fp32 with ``grad_clip=0`` is bit-identical to the
    monolithic path (same config, ``overlap="none"``) — per-bucket
    exchanges match the monolithic exchange slice-for-slice and the
    flat AdamW stream matches the tree update (tests/test_overlap.py).
    LAMB streams its moment updates and norm partials per bucket with
    one trailing trust pass (optim/lamb.py — bitwise-equal to the
    barrier form by construction); global-norm clip keeps the
    in-backward pipelined exchange but applies the flat update behind
    a barrier. Gradient accumulation
    stages every microbatch's backward and flushes only during the
    last one (the bucket is final only then); the accumulator is the
    fp32 stream buffer, so bf16-carry configs differ from the
    monolithic bf16 carry by that last rounding step (documented
    trade).
    """
    from repro.models import transformer as tr

    cfg = model.cfg
    ocfg = tcfg.optimizer
    accum = max(1, tcfg.het.accum_steps)
    q_impl = tcfg.het.quantize_impl
    dp = mesh_dp_axes(mesh)
    n_dp = dp_size(mesh)
    n_pods = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    L = cfg.num_layers
    ranks = n_pods if hier else n_dp
    inner_dp = (n_dp // n_pods) if hier else 1
    red_axis: Any = "pod" if hier else (dp if len(dp) > 1 else dp[0])
    axis_set = {"pod"} if hier else set(dp)
    rank_spec = P("pod", "data") if hier else P(dp)
    buf_spec = P("pod") if hier else P(dp if len(dp) > 1 else dp[0])
    nb, be = layout.num_buckets, layout.bucket_elems
    shard = be // ranks
    compress_flag = compress != "none"
    dmask = bkt.decay_mask(layout)
    segs = bkt.segment_ids(layout) if ocfg.name == "lamb" else None
    n_leaves = len(layout.sizes)
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    readiness = bkt.bucket_readiness(
        layout, _staged_leaf_pieces(params_shape, cfg))
    token_frontend = cfg.frontend == "token"
    inner_ctx = ParallelCtx(mesh=mesh,
                            dp_axes=("data",) if hier else (),
                            tp_axis=tp_axis(mesh))
    seg = tr.staged_uniform_segments(
        cfg, inner_ctx, label_smoothing=tcfg.label_smoothing)
    embed_fn, layer_fn = seg["embed_fn"], seg["layer_fn"]
    head_fn, head_keys = seg["head_fn"], seg["head_keys"]

    # stream-offset bookkeeping per top-level subtree, flatten order
    subtree_slots: Dict[str, list] = {}
    for (path, _), off, size in zip(
            jax.tree_util.tree_flatten_with_path(params_shape)[0],
            layout.offsets, layout.sizes):
        subtree_slots.setdefault(_path_top(path[0]), []).append(
            (off, size))

    def scatter_subtree(buf, top, grads, layer=None):
        """Scatter-add a landed grad subtree into the stream buffer."""
        leaves = jax.tree.leaves(grads)
        # zero-leaf subtrees (non-parametric norms) never reach the
        # stream
        slots = subtree_slots.get(top, [])
        assert len(leaves) == len(slots), (top, len(leaves), len(slots))
        for g, (off, size) in zip(leaves, slots):
            if layer is not None:
                per = size // L
                off, size = off + layer * per, per
            buf = buf.at[:, off:off + size].add(
                g.reshape(ranks, size).astype(jnp.float32))
        return buf

    def staged_microbatch(params, lps, mb, buf, flush=None,
                          on_loss=None):
        """One microbatch's staged forward + layer-by-layer backward.

        Gradients accumulate into ``buf`` ((ranks, padded_total) f32
        stream rows, one per reduction rank) as each stage's cotangent
        lands; ``flush(stage, buf)`` fires after every landing (the
        LAST microbatch wires the bucket flush pipeline there);
        ``on_loss(o, w)`` fires once the forward objective exists —
        before any flush, so the fused update hook can close over the
        global weight sum. Returns (buf, o, w), o/w per-rank sums.
        """
        emb_p = {"embed": params["embed"]} if token_frontend else {}
        x = jax.vmap(embed_fn, in_axes=(None, 0))(emb_p, mb["inputs"])
        # x: (ranks, rows, S, d) for BOTH frontends — stub inputs are
        # already (rows, S, d), so seq_len must come from the
        # post-embed activation, not from inputs.shape[-1]
        positions = jnp.arange(x.shape[-2])
        xs = [x]
        auxs = []
        for l in range(L):
            x, a = jax.vmap(layer_fn, in_axes=(None, 0, None))(
                lps[l], x, positions)
            xs.append(x)
            auxs.append(a)
        hp = {k: params[k] for k in head_keys}

        def head_stage(hp_, x_l, lab, wt):
            (ce, w), vjp = jax.vjp(
                lambda q, xx: head_fn(q, xx, lab, wt), hp_, x_l)
            g_hp, x_cot = vjp((jnp.ones((), jnp.float32),
                               jnp.zeros((), jnp.float32)))
            return ce, w, g_hp, x_cot

        ce, w, g_hp, x_cot = jax.vmap(
            head_stage, in_axes=(None, 0, 0, 0))(
            hp, xs[L], mb["labels"], mb["weights"])
        aux_tot = jnp.zeros_like(ce)
        for a in auxs:
            aux_tot = aux_tot + a
        o = ce + aux_tot * jax.lax.stop_gradient(w)
        if on_loss is not None:
            on_loss(o, w)
        for key in head_keys:
            buf = scatter_subtree(buf, key, g_hp[key])
        if flush is not None:
            flush(0, buf)
        w_sg = jax.lax.stop_gradient(w)

        def layer_stage(lp, x_l, xc, ac):
            _, vjp = jax.vjp(
                lambda q, xx: layer_fn(q, xx, positions), lp, x_l)
            return vjp((xc, ac))

        for l in reversed(range(L)):
            g_lp, x_cot = jax.vmap(
                layer_stage, in_axes=(None, 0, 0, 0))(
                lps[l], xs[l], x_cot, w_sg)
            buf = scatter_subtree(buf, "layers", g_lp, layer=l)
            if flush is not None:
                flush(L - l, buf)
        if token_frontend:
            def embed_stage(ep, i, xc):
                _, vjp = jax.vjp(lambda q: embed_fn(q, i), ep)
                return vjp(xc)[0]

            g_emb = jax.vmap(embed_stage, in_axes=(None, 0, 0))(
                emb_p, mb["inputs"], x_cot)
            buf = scatter_subtree(buf, "embed", g_emb["embed"])
        if flush is not None:
            flush(L + 1, buf)
        return buf, o, w

    def split_rank_microbatches(sb):
        """Per-rank accumulation split, matching the monolithic
        acc.split_microbatches row assignment (inner-rank-major, so
        every microbatch takes an equal slice of every inner DP
        rank's buffer)."""
        if accum == 1:
            return [sb]

        def split(a):
            b = a.shape[1]
            if b % (inner_dp * accum):
                raise ValueError(
                    f"rows {b} per reduction rank not divisible by "
                    f"accum {accum} x inner ranks {inner_dp}")
            a2 = a.reshape(ranks, inner_dp, accum,
                           b // inner_dp // accum, *a.shape[2:])
            a2 = jnp.swapaxes(a2, 1, 2)
            return a2.reshape(ranks, accum, b // accum, *a.shape[2:])

        s = {k: split(v) for k, v in sb.items()}
        return [jax.tree.map(lambda a: a[:, i], s) for i in range(accum)]

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        lr_step = state.opt.step + 1
        lr = schedules.learning_rate(ocfg, lr_step)
        params = state.params
        sb = jax.tree.map(
            lambda v: jax.lax.with_sharding_constraint(
                v.reshape(ranks, v.shape[0] // ranks, *v.shape[1:]),
                rank_spec), batch)
        mbs = split_rank_microbatches(sb)
        lps = [jax.tree.map(lambda a: a[l], params["layers"])
               for l in range(L)]
        pb = bkt.pack_buckets(params, layout)
        err_in = state.err if use_err else None   # (pods, nb, be)

        def prep(k, raw_k):
            """Send-side leg for bucket k: quantize/pack per rank at
            the top level (no collectives — it overlaps the previous
            bucket's in-flight exchange)."""
            x_k = raw_k.reshape(ranks, ranks, shard)
            if not compress_flag:
                return x_k, None
            e_k = (err_in[:, k].reshape(ranks, ranks, shard)
                   if use_err else None)
            pv = jax.vmap(
                lambda xk, ek: bkt.prepare_bucket(
                    xk, ek, compress=True, block_size=_BLOCK,
                    key=None, impl=q_impl, interpret=False),
                in_axes=(0, 0 if use_err else None))
            return pv(x_k, e_k)

        def exchange(k, prepared):
            """Link + receive legs for ONE bucket, in its own small
            manual region — the only collectives in the program, so
            they interleave with the staged backward in program
            order."""
            payload, resid1 = prepared
            if compress_flag and use_err:
                def region(pl, rs):
                    onehot = compat.manual_axis_onehot(
                        red_axis, ranks, tie=pl)
                    red, ne = bkt.exchange_prepared_bucket(
                        pl[0], rs[0], axis=red_axis, axis_size=ranks,
                        compress=True, block_size=_BLOCK, impl=q_impl,
                        interpret=False, onehot=onehot)
                    return red, ne[None]

                return compat.shard_map(
                    region, mesh=mesh, in_specs=(buf_spec, buf_spec),
                    out_specs=(P(), buf_spec), axis_names=axis_set,
                    check_vma=False)(payload, resid1)

            def region(pl):
                onehot = compat.manual_axis_onehot(
                    red_axis, ranks, tie=pl)
                red, _ = bkt.exchange_prepared_bucket(
                    pl[0], None, axis=red_axis, axis_size=ranks,
                    compress=compress_flag, block_size=_BLOCK,
                    impl=q_impl, interpret=False, onehot=onehot)
                return red

            red = compat.shard_map(
                region, mesh=mesh, in_specs=buf_spec, out_specs=P(),
                axis_names=axis_set, check_vma=False)(payload)
            return red, None

        cell: Dict[str, Any] = {}
        if fused_stream:
            if ocfg.name == "lamb":
                # stream moments + per-leaf norm partials per bucket;
                # the trust-scaled step itself trails (finish below)
                def hook(ssq, red_k, k):
                    g_k = red_k * cell["inv_w"]
                    pf, upd, mf, vf = adam.flat_adamw_terms(
                        pb[k], g_k, state.opt.m[k], state.opt.v[k],
                        lr_step, ocfg, decay_mask=dmask[k])
                    psq, usq = lamb.bucket_norm_terms(
                        pf, upd, segs[k], n_leaves)
                    return (ssq + jnp.sum(g_k * g_k),
                            (pf, upd, mf, vf, psq, usq))
            else:
                def hook(ssq, red_k, k):
                    g_k = red_k * cell["inv_w"]
                    out = adam.apply_update_flat(
                        pb[k], g_k, state.opt.m[k], state.opt.v[k],
                        lr_step, ocfg, lr, decay_mask=dmask[k])
                    return ssq + jnp.sum(g_k * g_k), out

            pipeline = bkt.BucketFlushPipeline(
                readiness, prep, exchange, bucket_fn=hook,
                fn_carry=jnp.zeros((), jnp.float32))
        else:
            pipeline = bkt.BucketFlushPipeline(readiness, prep,
                                               exchange)

        def flush(stage, buf):
            pipeline.flush_ready_buckets(
                stage, lambda k: buf[:, k * be:(k + 1) * be])

        buf = jax.lax.with_sharding_constraint(
            jnp.zeros((ranks, layout.padded_total), jnp.float32),
            buf_spec)
        o_acc = jnp.zeros((ranks,), jnp.float32)
        w_acc = jnp.zeros((ranks,), jnp.float32)
        for i, mb in enumerate(mbs):
            if i == accum - 1:
                def on_loss(o_mb, w_mb, _oa=o_acc, _wa=w_acc):
                    o_t, w_t = _oa + o_mb, _wa + w_mb
                    cell["o"], cell["w"] = o_t, w_t
                    w_glob = jnp.sum(w_t)
                    cell["w_glob"] = w_glob
                    cell["inv_w"] = 1.0 / jnp.maximum(w_glob, 1e-9)

                buf, o_mb, w_mb = staged_microbatch(
                    params, lps, mb, buf, flush=flush, on_loss=on_loss)
            else:
                buf, o_mb, w_mb = staged_microbatch(params, lps, mb,
                                                    buf)
                o_acc = o_acc + o_mb
                w_acc = w_acc + w_mb

        outs, errs, fc = pipeline.finish()
        o, w = jnp.sum(cell["o"]), cell["w_glob"]
        if fused_stream and ocfg.name == "lamb":
            # finish() hands outs back in BUCKET-INDEX order whatever
            # order the buckets flushed in — so the partial-norm
            # combination below is the canonical one apply_update_flat
            # uses, and the streamed step is bitwise the barrier step
            pf = jnp.stack([row[0] for row in outs])
            upd = jnp.stack([row[1] for row in outs])
            trust_v = lamb.trust_from_norms(
                lamb.combine_norm_terms([row[4] for row in outs]),
                lamb.combine_norm_terms([row[5] for row in outs]))
            new_pb = lamb.apply_trust(
                pf, upd, lr, segs, trust_v).astype(pb.dtype)
            new_m = jnp.stack(
                [row[2] for row in outs]).astype(state.opt.m.dtype)
            new_v = jnp.stack(
                [row[3] for row in outs]).astype(state.opt.v.dtype)
            gnorm = jnp.sqrt(fc)
            trust = jnp.mean(trust_v[:n_leaves])
        elif fused_stream:
            new_pb = jnp.stack([row[0] for row in outs])
            new_m = jnp.stack([row[1] for row in outs])
            new_v = jnp.stack([row[2] for row in outs])
            gnorm = jnp.sqrt(fc)
            trust = jnp.ones((), jnp.float32)
        else:
            red = jnp.stack(outs)
            new_pb, new_m, new_v, gnorm, trust = _flat_barrier_update(
                pb, red, state.opt.m, state.opt.v, lr_step, ocfg, lr,
                inv_w=cell["inv_w"], dmask=dmask, segs=segs,
                n_leaves=n_leaves)
        new_params = bkt.unpack_buckets(new_pb, layout)
        new_err = state.err
        if use_err and errs is not None:
            new_err = jnp.stack(errs, axis=1).reshape(ranks, nb, be)
        loss = weighting.finalize(o, w)
        metrics = {"loss": loss, "weight": w, "grad_norm": gnorm,
                   "lr": lr}
        if ocfg.name == "lamb":
            metrics["trust_ratio"] = trust
        new_state = TrainState(
            params=new_params,
            opt=adam.AdamState(step=lr_step, m=new_m, v=new_v),
            err=new_err)
        return new_state, metrics

    return step


# --------------------------------------------------------------------------
# pipeline-parallel step (HetConfig.pipeline_stages > 1)
# --------------------------------------------------------------------------


def _pipe_send(x: jnp.ndarray, mesh: Mesh, spec: P,
               direction: int) -> jnp.ndarray:
    """Move a stage-boundary value to the next (+1) / previous (-1)
    stage along the "pipe" axis.

    Every stage executes the full program in program order on
    pipe-replicated values, so the ring ppermute is value-preserving —
    it exists to hand the runtime the placement edge between
    consecutive stages (the activation / cotangent hop the modeled
    timeline charges to DCN). On the compat stack (no native manual
    collectives — old jaxlib check-fails ppermute around the staged
    VJPs) the hop degrades to a sharding constraint; without a pipe
    axis on the mesh it is the identity.
    """
    if "pipe" not in mesh.axis_names:
        return x
    if not compat.NATIVE_MANUAL_COLLECTIVES:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    n = mesh.shape["pipe"]
    perm = [(i, (i + direction) % n) for i in range(n)]
    return compat.shard_map(
        lambda v: jax.lax.ppermute(v, "pipe", perm),
        mesh=mesh, in_specs=spec, out_specs=spec,
        axis_names={"pipe"}, check_vma=False)(x)


def _pipeline_leaf_pieces(params_shape: Any, cfg: ModelConfig,
                          splan: pipe.StagePlan):
    """Per-leaf ``(offset_within_leaf, n, flush_stage)`` pieces for the
    pipeline's bucket engine (cf. ``_staged_leaf_pieces``).

    Flush stages follow the LAST microbatch's backward completion
    order: the head lands first (flush stage 0), layer ``l`` at the B
    event of its pipeline stage (flush stage ``S - 1 -
    stage_of_layer(l)``), the embedding table last (flush stage ``S`` —
    a tied table also receives a head-stage contribution, so its grad
    is only final at the end). Feeds
    ``core/buckets.py::bucket_readiness``.
    """
    from repro.models import transformer as tr

    L = cfg.num_layers
    S = splan.num_stages
    head_keys = set(tr.head_param_keys(cfg))
    pieces = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            params_shape)[0]:
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        top = _path_top(path[0])
        if top == "layers":
            if n % L:
                raise ValueError(
                    f"stacked leaf {jax.tree_util.keystr(path)} of "
                    f"{n} elements does not split into {L} layers")
            per = n // L
            pieces.append([(l * per, per,
                            S - 1 - splan.stage_of_layer(l))
                           for l in range(L)])
        elif top == "embed":
            pieces.append([(0, n, S)])
        elif top in head_keys:
            pieces.append([(0, n, 0)])
        else:
            raise ValueError(
                f"pipeline_stages > 1: unexpected param subtree "
                f"'{top}' (uniform stack expects embed / final_norm / "
                f"lm_head / layers)")
    return pieces


def _build_pipeline_step(model: Model, tcfg: TrainConfig, mesh: Mesh, *,
                         splan: pipe.StagePlan,
                         layout: Optional[bkt.BucketLayout]):
    """The pipelined train step: capacity-sized contiguous stages, the
    accumulation microbatches streamed through them in 1F1B (or GPipe)
    program order.

    The step emits one deterministic global sequence of per-stage VJP
    segments (core/pipeline.py::program_order): each F event runs one
    stage's forward slice and hands the boundary activation to the next
    stage through a ``_pipe_send`` region; each B event runs the
    stage's VJP, scatter-adds the stage-slice gradients into the
    accumulator, and sends the input cotangent back. Because every
    stage's B events occur in microbatch order and stage slices are
    disjoint, the per-element gradient accumulation reproduces
    ``accumulate.unrolled_accumulate``'s add order — fp32 with
    ``scan_layers=False`` is bit-identical to pure DP of the same
    config (``pipeline_stages=1``), whatever the stage partition
    (BENCH_pipeline.json invariant).

    Reduction: with ``grad_reduction="allreduce"`` (``layout`` None)
    XLA reduces from the shardings exactly as the monolithic path;
    with ``"bucketed_allreduce"`` the grads live in the flat (ranks,
    padded_total) stream and each stage's buckets flush through their
    own small exchange regions the moment the last microbatch's B event
    for that stage lands (readiness from ``_pipeline_leaf_pieces``) —
    per-stage reduction overlapping the remaining drain, mirroring
    ``overlap="backward"``'s engine. The tree-form optimizer runs after
    the drain (``overlap`` must be "none" with pipelining —
    HetConfig.validate), so moments stay a pytree and checkpoints
    restore bit-exactly across stage plans, including pure DP.

    Exactness on the bucketed path: losses are bit-identical to the
    stages=1 bucketed step, but parameters can drift by 1-2 ulp — XLA
    fuses the attention backward differently once the program is cut at
    a stage boundary (verified: the drift appears for ANY vjp cut
    between layers, including the per-layer granularity, and sits in
    the softmax-backward reduction feeding dq/dk/dv). A documented
    trade like backward-overlap's bf16 carry; the allreduce path above
    carries the bit-exactness claim (BENCH_pipeline.json).
    """
    from repro.models import transformer as tr

    cfg = model.cfg
    ocfg = tcfg.optimizer
    M = max(1, tcfg.het.accum_steps)
    S = splan.num_stages
    ranges = splan.stage_ranges()
    events = pipe.program_order(S, M, schedule=tcfg.het.pipeline_schedule)
    dp = mesh_dp_axes(mesh)
    n_dp = dp_size(mesh)
    token_frontend = cfg.frontend == "token"
    L = cfg.num_layers

    def carry_dtype(p):
        # same bf16 passthrough as compute_grads' accumulation carry
        return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

    if layout is None:
        # ---- plain-SPMD path (grad_reduction="allreduce") ------------
        ctx = make_parallel_ctx(mesh)
        seg = tr.pipeline_stage_fns(cfg, ctx, ranges,
                                    label_smoothing=tcfg.label_smoothing)
        embed_fn, head_fn = seg["embed_fn"], seg["head_fn"]
        head_keys, stage_fwd = seg["head_keys"], seg["stage_fwd"]
        act_spec = shr.stage_activation_spec(
            mesh, tcfg.shape.global_batch // M)

        def step(state: TrainState, batch: Dict
                 ) -> Tuple[TrainState, Dict]:
            lr_step = state.opt.step + 1
            lr = schedules.learning_rate(ocfg, lr_step)
            params = state.params
            split = acc.split_microbatches(batch, M, num_ranks=n_dp)
            mbs = [jax.tree.map(lambda a: a[i], split) for i in range(M)]
            slices = [jax.tree.map(lambda a: a[r0:r1], params["layers"])
                      for (r0, r1) in ranges]
            emb_p = {"embed": params["embed"]} if token_frontend else {}
            hp = {k: params[k] for k in head_keys}
            g_acc = jax.tree.map(
                lambda p: jnp.zeros(p.shape, carry_dtype(p)), params)
            o_acc = jnp.zeros((), jnp.float32)
            w_acc = jnp.zeros((), jnp.float32)
            x_in: Dict = {}
            vjps: Dict = {}
            head_vjps: Dict = {}
            embed_vjps: Dict = {}
            cots: Dict = {}
            w_sgs: Dict = {}
            head_emb: Dict = {}
            for (s, kind, m) in events:
                mb = mbs[m]
                if kind == pipe.FWD:
                    if s == 0:
                        if token_frontend:
                            x0, evjp = jax.vjp(
                                lambda q: embed_fn(q, mb["inputs"]),
                                emb_p)
                            embed_vjps[m] = evjp
                        else:
                            x0 = embed_fn(emb_p, mb["inputs"])
                        xa = (x0, jnp.zeros((), jnp.float32))
                    else:
                        xa = x_in.pop((s, m))
                    positions = jnp.arange(xa[0].shape[-2])
                    (x_out, a_out), vjp = jax.vjp(
                        lambda q, xx, aa: stage_fwd[s](q, xx, aa,
                                                       positions),
                        slices[s], xa[0], xa[1])
                    vjps[(s, m)] = vjp
                    if s < S - 1:
                        x_in[(s + 1, m)] = (
                            _pipe_send(x_out, mesh, act_spec, +1),
                            a_out)
                    else:
                        (ce, w), hvjp = jax.vjp(
                            lambda q, xx: head_fn(q, xx, mb["labels"],
                                                  mb["weights"]),
                            hp, x_out)
                        w_sg = jax.lax.stop_gradient(w)
                        o_acc = o_acc + (ce + a_out * w_sg)
                        w_acc = w_acc + w
                        head_vjps[m] = hvjp
                        w_sgs[m] = w_sg
                else:
                    if s == S - 1:
                        g_hp, x_cot = head_vjps.pop(m)(
                            (jnp.ones((), jnp.float32),
                             jnp.zeros((), jnp.float32)))
                        for key in head_keys:
                            if key == "embed":
                                # tied table: held until the stage-0 B
                                # event and combined with the gather
                                # cotangent there — ONE add per
                                # microbatch, the monolithic VJP's
                                # association
                                head_emb[m] = g_hp["embed"]
                                continue
                            g_acc[key] = jax.tree.map(
                                lambda a, b: a + b.astype(a.dtype),
                                g_acc[key], g_hp[key])
                        cot = (x_cot, w_sgs[m])
                    else:
                        cot = cots.pop((s, m))
                    g_sl, x_cot, a_cot = vjps.pop((s, m))(cot)
                    r0 = ranges[s][0]
                    g_acc["layers"] = jax.tree.map(
                        lambda a, g: a.at[r0:r0 + g.shape[0]].add(
                            g.astype(a.dtype)),
                        g_acc["layers"], g_sl)
                    if s > 0:
                        cots[(s - 1, m)] = (
                            _pipe_send(x_cot, mesh, act_spec, -1),
                            a_cot)
                    elif token_frontend:
                        g_emb = embed_vjps.pop(m)(x_cot)[0]["embed"]
                        if m in head_emb:
                            g_emb = g_emb + head_emb.pop(m)
                        g_acc["embed"] = g_acc["embed"] + \
                            g_emb.astype(g_acc["embed"].dtype)
            loss = weighting.finalize(o_acc, w_acc)
            grads = weighting.scale_grads(g_acc, w_acc)
            opt_apply = (lamb.apply_update if ocfg.name == "lamb"
                         else adam.apply_update)
            new_params, opt, met = opt_apply(params, grads, state.opt,
                                             ocfg, lr)
            metrics = {"loss": loss, "weight": w_acc, **met}
            return TrainState(params=new_params, opt=opt,
                              err=state.err), metrics

        return step

    # ---- bucketed path (grad_reduction="bucketed_allreduce") ---------
    # rank-major vmapped stage VJPs with the flat f32 gradient stream;
    # per-stage bucket flushes through small manual exchange regions
    # (cf. _build_backward_overlap_step — same engine, pipeline order)
    inner_ctx = ParallelCtx(mesh=mesh, dp_axes=(), tp_axis=tp_axis(mesh))
    seg = tr.pipeline_stage_fns(cfg, inner_ctx, ranges,
                                label_smoothing=tcfg.label_smoothing)
    embed_fn, head_fn = seg["embed_fn"], seg["head_fn"]
    head_keys, stage_fwd = seg["head_keys"], seg["stage_fwd"]
    ranks = n_dp
    red_axis: Any = dp if len(dp) > 1 else dp[0]
    axis_set = set(dp)
    rank_spec = P(dp)
    buf_spec = P(dp if len(dp) > 1 else dp[0])
    be = layout.bucket_elems
    shard = be // ranks
    q_impl = tcfg.het.quantize_impl
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    readiness = bkt.bucket_readiness(
        layout, _pipeline_leaf_pieces(params_shape, cfg, splan))
    subtree_slots: Dict[str, list] = {}
    for (path, _), off, size in zip(
            jax.tree_util.tree_flatten_with_path(params_shape)[0],
            layout.offsets, layout.sizes):
        subtree_slots.setdefault(_path_top(path[0]), []).append(
            (off, size))

    def scatter_subtree(buf, top, grads, layers=None):
        """Scatter-add a landed grad subtree into the stream buffer
        (stage slices index a contiguous per-layer region)."""
        leaves = jax.tree.leaves(grads)
        slots = subtree_slots.get(top, [])
        assert len(leaves) == len(slots), (top, len(leaves), len(slots))
        for g, (off, size) in zip(leaves, slots):
            if layers is not None:
                r0, r1 = layers
                per = size // L
                off, size = off + r0 * per, (r1 - r0) * per
            buf = buf.at[:, off:off + size].add(
                g.reshape(ranks, size).astype(jnp.float32))
        return buf

    def split_rank_microbatches(sb):
        """Per-rank accumulation split (inner_dp == 1 counterpart of
        the backward-overlap splitter — rows per rank cut into M equal
        contiguous microbatch slices)."""
        if M == 1:
            return [sb]

        def split(a):
            b = a.shape[1]
            if b % M:
                raise ValueError(
                    f"rows {b} per reduction rank not divisible by "
                    f"accum {M}")
            return a.reshape(ranks, M, b // M, *a.shape[2:])

        s = {k: split(v) for k, v in sb.items()}
        return [jax.tree.map(lambda a: a[:, i], s) for i in range(M)]

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        lr_step = state.opt.step + 1
        lr = schedules.learning_rate(ocfg, lr_step)
        params = state.params
        sb = jax.tree.map(
            lambda v: jax.lax.with_sharding_constraint(
                v.reshape(ranks, v.shape[0] // ranks, *v.shape[1:]),
                rank_spec), batch)
        mbs = split_rank_microbatches(sb)
        slices = [jax.tree.map(lambda a: a[r0:r1], params["layers"])
                  for (r0, r1) in ranges]
        emb_p = {"embed": params["embed"]} if token_frontend else {}
        hp = {k: params[k] for k in head_keys}

        def prep(k, raw_k):
            return raw_k.reshape(ranks, ranks, shard), None

        def exchange(k, prepared):
            payload, _ = prepared

            def region(pl):
                onehot = compat.manual_axis_onehot(red_axis, ranks,
                                                   tie=pl)
                red, _ = bkt.exchange_prepared_bucket(
                    pl[0], None, axis=red_axis, axis_size=ranks,
                    compress=False, block_size=_BLOCK, impl=q_impl,
                    interpret=False, onehot=onehot)
                return red

            red = compat.shard_map(
                region, mesh=mesh, in_specs=buf_spec, out_specs=P(),
                axis_names=axis_set, check_vma=False)(payload)
            return red, None

        pipeline_fl = bkt.BucketFlushPipeline(readiness, prep, exchange)

        def flush(stage, buf):
            pipeline_fl.flush_ready_buckets(
                stage, lambda k: buf[:, k * be:(k + 1) * be])

        buf = jax.lax.with_sharding_constraint(
            jnp.zeros((ranks, layout.padded_total), jnp.float32),
            buf_spec)
        o_acc = jnp.zeros((ranks,), jnp.float32)
        w_acc = jnp.zeros((ranks,), jnp.float32)
        x_in: Dict = {}
        stage_in: Dict = {}
        head_in: Dict = {}
        cots: Dict = {}
        w_sgs: Dict = {}
        head_emb: Dict = {}
        for (s, kind, m) in events:
            mb = mbs[m]
            if kind == pipe.FWD:
                if s == 0:
                    x0 = jax.vmap(embed_fn, in_axes=(None, 0))(
                        emb_p, mb["inputs"])
                    xa = (x0, jnp.zeros((ranks,), jnp.float32))
                else:
                    xa = x_in.pop((s, m))
                stage_in[(s, m)] = xa
                positions = jnp.arange(xa[0].shape[-2])
                x_out, a_out = jax.vmap(
                    lambda sl_, x_, a_: stage_fwd[s](sl_, x_, a_,
                                                     positions),
                    in_axes=(None, 0, 0))(slices[s], *xa)
                if s < S - 1:
                    x_in[(s + 1, m)] = (
                        _pipe_send(x_out, mesh, rank_spec, +1), a_out)
                else:
                    ce, w = jax.vmap(
                        head_fn, in_axes=(None, 0, 0, 0))(
                        hp, x_out, mb["labels"], mb["weights"])
                    w_sg = jax.lax.stop_gradient(w)
                    o_acc = o_acc + (ce + a_out * w_sg)
                    w_acc = w_acc + w
                    head_in[m] = x_out
                    w_sgs[m] = w_sg
            else:
                if s == S - 1:
                    def head_stage(hp_, x_l, lab, wt):
                        _, vjp = jax.vjp(
                            lambda q, xx: head_fn(q, xx, lab, wt),
                            hp_, x_l)
                        return vjp((jnp.ones((), jnp.float32),
                                    jnp.zeros((), jnp.float32)))

                    g_hp, x_cot = jax.vmap(
                        head_stage, in_axes=(None, 0, 0, 0))(
                        hp, head_in.pop(m), mb["labels"],
                        mb["weights"])
                    for key in head_keys:
                        if key == "embed":
                            # tied table: one add per microbatch at the
                            # stage-0 B event (see the allreduce path)
                            head_emb[m] = g_hp["embed"]
                            continue
                        buf = scatter_subtree(buf, key, g_hp[key])
                    cot = (x_cot, w_sgs[m])
                else:
                    cot = cots.pop((s, m))
                xa = stage_in.pop((s, m))
                positions = jnp.arange(xa[0].shape[-2])

                def stage_bwd(sl_, x_, a_, xc, ac):
                    _, vjp = jax.vjp(
                        lambda q, xx, aa: stage_fwd[s](q, xx, aa,
                                                       positions),
                        sl_, x_, a_)
                    return vjp((xc, ac))

                g_sl, x_cot, a_cot = jax.vmap(
                    stage_bwd, in_axes=(None, 0, 0, 0, 0))(
                    slices[s], xa[0], xa[1], cot[0], cot[1])
                buf = scatter_subtree(buf, "layers", g_sl,
                                      layers=ranges[s])
                if m == M - 1:
                    flush(S - 1 - s, buf)
                if s > 0:
                    cots[(s - 1, m)] = (
                        _pipe_send(x_cot, mesh, rank_spec, -1), a_cot)
                else:
                    if token_frontend:
                        def embed_stage(ep, i, xc):
                            _, vjp = jax.vjp(
                                lambda q: embed_fn(q, i), ep)
                            return vjp(xc)[0]

                        g_emb = jax.vmap(
                            embed_stage, in_axes=(None, 0, 0))(
                            emb_p, mb["inputs"], x_cot)["embed"]
                        if m in head_emb:
                            g_emb = g_emb + head_emb.pop(m)
                        buf = scatter_subtree(buf, "embed", g_emb)
                    if m == M - 1:
                        flush(S, buf)
        outs, _, _ = pipeline_fl.finish()
        red = jnp.stack(outs)
        grads = bkt.unpack_buckets(red, layout)
        o, w = jnp.sum(o_acc), jnp.sum(w_acc)
        loss = weighting.finalize(o, w)
        grads = weighting.scale_grads(grads, w)
        opt_apply = (lamb.apply_update if ocfg.name == "lamb"
                     else adam.apply_update)
        new_params, opt, met = opt_apply(params, grads, state.opt,
                                         ocfg, lr)
        metrics = {"loss": loss, "weight": w, **met}
        return TrainState(params=new_params, opt=opt,
                          err=state.err), metrics

    return step


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------


def build_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh
                     ) -> Callable[[TrainState, Dict], Tuple[TrainState,
                                                             Dict]]:
    validate_train_config(model, tcfg, mesh)
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)
    ocfg = tcfg.optimizer
    accum = max(1, tcfg.het.accum_steps)
    hier = (tcfg.het.grad_reduction == "hierarchical"
            and "pod" in mesh.axis_names)
    bucketed_ar = tcfg.het.grad_reduction == "bucketed_allreduce"
    compress = tcfg.het.compression if hier else "none"
    layout = bucket_layout(model, tcfg, mesh) if (hier or bucketed_ar) \
        else None
    # bucketed_ar always has a layout here: validate_train_config
    # raised on a missing DP axis, HetConfig.validate on bucket_mb <= 0
    use_err = _err_enabled(tcfg, mesh)
    q_impl = tcfg.het.quantize_impl
    n_dp = dp_size(mesh)
    dp = mesh_dp_axes(mesh)
    n_pods = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    overlap = _overlap_enabled(tcfg, mesh)
    if overlap and layout is None:
        raise ValueError("HetConfig.overlap='buckets' needs a bucket "
                         "layout (bucket_mb > 0 and reduction axes)")
    # the fused per-bucket pipeline can stream the optimizer as each
    # bucket lands — AdamW entirely, LAMB up to one trailing
    # trust-ratio pass (optim/lamb.py); global-norm clipping needs
    # every bucket BEFORE the first moment update, so it keeps the
    # pipelined exchange but updates behind a barrier
    fused_stream = overlap and ocfg.grad_clip <= 0

    if tcfg.het.pipeline_stages > 1:
        # capacity-sized pipeline stages with 1F1B microbatching.
        # HetConfig.validate pinned overlap="none" and reduction to
        # allreduce / bucketed_allreduce, so `layout` is exactly the
        # bucket grid for the per-stage flushes (or None for plain
        # allreduce) and the optimizer state stays a pytree
        splan = stage_plan_for(model, tcfg)
        pipe_step = _build_pipeline_step(model, tcfg, mesh, splan=splan,
                                         layout=layout)
        specs = state_specs(model, tcfg, mesh)
        bspecs = shr.batch_specs(cfg, mesh, tcfg.shape.global_batch)
        return jax.jit(
            pipe_step,
            in_shardings=(shr.named(mesh, specs),
                          shr.named(mesh, bspecs)),
            out_shardings=(shr.named(mesh, specs), None),
            donate_argnums=(0,),
        )

    if overlap and tcfg.het.overlap == "backward":
        # staged layer-by-layer backward with in-backprop bucket
        # flushes — built as its own step function (the schedule is a
        # top-level interleaving of vmapped VJP stages and per-bucket
        # exchange regions, not a shard_map-wrapped monolith)
        bwd_step = _build_backward_overlap_step(
            model, tcfg, mesh, layout=layout, hier=hier,
            compress=compress, use_err=use_err,
            fused_stream=fused_stream)
        specs = state_specs(model, tcfg, mesh)
        bspecs = shr.batch_specs(cfg, mesh, tcfg.shape.global_batch)
        return jax.jit(
            bwd_step,
            in_shardings=(shr.named(mesh, specs),
                          shr.named(mesh, bspecs)),
            out_shardings=(shr.named(mesh, specs), None),
            donate_argnums=(0,),
        )

    # inside a manual region the manual axes must not appear in sharding
    # constraints — hierarchical keeps "data" automatic inside the pod
    # region; bucketed_allreduce makes the whole DP set manual
    if hier:
        inner_ctx = ParallelCtx(mesh=mesh, dp_axes=("data",),
                                tp_axis=tp_axis(mesh))
        inner_dp = n_dp // n_pods
    elif bucketed_ar:
        inner_ctx = ParallelCtx(mesh=mesh, dp_axes=(),
                                tp_axis=tp_axis(mesh))
        inner_dp = 1
    else:
        inner_ctx = ctx
        inner_dp = n_dp

    def compute_grads(params, batch):
        """Returns (grad_of_sums, obj_sum, weight_sum) — unscaled."""
        def objective(p, b):
            o, w, _ = model.loss_fn(
                p, b, inner_ctx, label_smoothing=tcfg.label_smoothing)
            return o, w

        grad_fn = jax.value_and_grad(objective, has_aux=True)
        if accum == 1:
            (o, w), g = grad_fn(params, batch)
            return g, o, w
        mbs = acc.split_microbatches(batch, accum, num_ranks=inner_dp)

        # accumulation carry dtype: fp32, except when params are stored
        # bf16 (arctic/deepseek giants) where an fp32 carry alone would
        # blow the 16 GB budget — bf16 carry, documented in EXPERIMENTS
        def carry_dtype(p):
            return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

        if not cfg.scan_layers:
            # unrolled-program class (scan_layers=False, required by
            # overlap="backward"): keep the accumulation unrolled too
            # so the staged backward stays bit-identical at accum > 1
            return acc.unrolled_accumulate(grad_fn, params, mbs,
                                           carry_dtype=carry_dtype)
        return acc.scan_accumulate(grad_fn, params, mbs,
                                   carry_dtype=carry_dtype)

    def apply_pod_reduce(g, err):
        """The cross-pod leg: bucketed engine or legacy per-leaf walk."""
        if layout is not None:
            g, ne = _reduce_bucketed(
                g, err if use_err else None, axis="pod",
                axis_size=n_pods, compress=compress, layout=layout,
                impl=q_impl)
            return g, (ne if ne is not None else ())
        return _cross_pod_reduce(g, err, compress, n_pods)

    def vmapped_rank_grads(params, batch, ranks, rank_spec):
        """Per-rank stacked grads computed OUTSIDE the manual region.

        Old jaxlibs cannot lower grad-of-scan (the layer stack, chunked
        CE, accumulation) inside a partially-manual shard_map region —
        the SPMD partitioner check-fails. Fallback: reshape the batch
        rank-major, vmap the grad over the rank dim (plain SPMD — the
        vmap dim shards over the reduction axes), and enter the manual
        region only for the reduction itself.
        """
        sb = jax.tree.map(
            lambda v: jax.lax.with_sharding_constraint(
                v.reshape(ranks, v.shape[0] // ranks, *v.shape[1:]),
                rank_spec), batch)
        g, o, w = jax.vmap(compute_grads, in_axes=(None, 0))(params, sb)
        return g, jnp.sum(o), jnp.sum(w)

    # ---- fused overlap step (HetConfig.overlap="buckets") ---------------
    # The optimizer moves INSIDE the manual region: the per-bucket
    # pipeline exchanges bucket k while bucket k+1 quantizes, and the
    # flat-view AdamW update for bucket k runs the moment it lands.
    # The packed moments enter/leave the region replicated over the
    # reduction axes; every rank computes the identical update.
    if overlap:
        dmask = bkt.decay_mask(layout)
        segs = bkt.segment_ids(layout) if ocfg.name == "lamb" else None
        n_leaves = len(layout.sizes)
        red_axis: Any = "pod" if hier else (dp if len(dp) > 1 else dp[0])
        red_size = n_pods if hier else n_dp

        def fused_reduce_update(g, params, m, v, e, w_sum, lr_step, lr):
            """Inside shard_map(manual over the reduction axes).

            ``g``: this rank's unreduced grad tree; ``e``: this rank's
            (nb, be) error slice or None; ``w_sum``: the GLOBAL weight
            sum. Returns (params', m', v', err'(nb, be) | None, gnorm,
            mean trust ratio — 1.0 for AdamW).
            """
            gb = bkt.pack_buckets(g, layout)
            pb = bkt.pack_buckets(params, layout)
            inv_w = 1.0 / jnp.maximum(w_sum, 1e-9)
            kwargs = dict(axis=red_axis, axis_size=red_size,
                          compress=(compress != "none"),
                          block_size=_BLOCK, impl=q_impl)
            if fused_stream and ocfg.name != "lamb":
                def hook(ssq, red_k, xs_k, k):
                    p_k, m_k, v_k, dm_k = xs_k
                    g_k = red_k * inv_w
                    out = adam.apply_update_flat(
                        p_k, g_k, m_k, v_k, lr_step, ocfg, lr,
                        decay_mask=dm_k)
                    return ssq + jnp.sum(g_k * g_k), out

                outs, new_e, ssq = bkt.exchange_buckets_overlapped(
                    gb, e, bucket_fn=hook,
                    fn_carry=jnp.zeros((), jnp.float32),
                    bucket_xs=(pb, m, v, dmask), **kwargs)
                new_pb, new_m, new_v = outs
                gnorm = jnp.sqrt(ssq)
                trust = jnp.ones((), jnp.float32)
            else:
                # clip barrier, and ALL of LAMB in this after-backward
                # engine: fusing LAMB's hook into the per-bucket scan
                # deterministically perturbs how XLA compiles the
                # whole-module gradient/reduction program (~0.4% of
                # reduced-grad elements move 1 ulp, measured across
                # every hook/optimization_barrier variant), which
                # breaks the backward==buckets bitwise contract
                # (tests/test_overlap.py). The backward-overlap flush
                # pipeline streams LAMB bitwise-safely; here the
                # barrier form is the bit-exact choice — and the
                # exchange is already fully overlapped bucket-to-
                # bucket, so only the optimizer pass trails.
                red, new_e, _ = bkt.exchange_buckets_overlapped(
                    gb, e, **kwargs)
                new_pb, new_m, new_v, gnorm, trust = \
                    _flat_barrier_update(
                        pb, red, m, v, lr_step, ocfg, lr, inv_w=inv_w,
                        dmask=dmask, segs=segs, n_leaves=n_leaves)
            return (bkt.unpack_buckets(new_pb, layout), new_m, new_v,
                    new_e, gnorm, trust)

        def overlap_step(state: TrainState, batch: Dict
                         ) -> Tuple[TrainState, Dict]:
            lr_step = state.opt.step + 1
            lr = schedules.learning_rate(ocfg, lr_step)
            err_in = state.err if use_err else ()
            err_spec = P("pod") if use_err else P()
            axes = {"pod"} if hier else set(dp)
            batch_spec = P("pod") if hier else P(dp)

            def unslice_err(err):
                return (err.reshape(layout.num_buckets,
                                    layout.bucket_elems)
                        if use_err else None)

            def reslice_err(new_e, err):
                return (new_e.reshape(1, layout.num_buckets,
                                      layout.bucket_elems)
                        if use_err else err)

            if compat.NATIVE_MANUAL_COLLECTIVES:
                pspecs_in = state_specs(model, tcfg, mesh).params

                def local(params, b, err, m, v, step_no, lr_in):
                    g, o, w = compute_grads(params, b)
                    if hier:
                        # re-pin lost (data, model) layouts (see the
                        # hierarchical branch below)
                        g = jax.tree.map(
                            lambda gr, s:
                            jax.lax.with_sharding_constraint(gr, s),
                            g, pspecs_in)
                    o = jax.lax.psum(o, red_axis)
                    w = jax.lax.psum(w, red_axis)
                    np_, nm, nv, ne, gn, tr = fused_reduce_update(
                        g, params, m, v, unslice_err(err), w,
                        step_no, lr_in)
                    return (np_, nm, nv, reslice_err(ne, err), o, w,
                            gn, tr)

                (new_params, new_m, new_v, new_err, o, w, gnorm,
                 trust) = compat.shard_map(
                    local, mesh=mesh,
                    in_specs=(P(), batch_spec, err_spec, P(), P(),
                              P(), P()),
                    out_specs=(P(), P(), P(), err_spec, P(), P(),
                               P(), P()),
                    axis_names=axes, check_vma=False,
                )(state.params, batch, err_in, state.opt.m,
                  state.opt.v, lr_step, lr)
            else:
                ranks = n_pods if hier else n_dp
                rank_spec = P("pod", "data") if hier else P(dp)
                g, o, w = vmapped_rank_grads(state.params, batch, ranks,
                                             rank_spec)

                def reduce_update(gl, err, params, m, v, w_sum,
                                  step_no, lr_in):
                    gg = jax.tree.map(lambda a: a[0], gl)
                    np_, nm, nv, ne, gn, tr = fused_reduce_update(
                        gg, params, m, v, unslice_err(err), w_sum,
                        step_no, lr_in)
                    return np_, nm, nv, reslice_err(ne, err), gn, tr

                (new_params, new_m, new_v, new_err, gnorm, trust) = \
                    compat.shard_map(
                        reduce_update, mesh=mesh,
                        in_specs=(P("pod") if hier else P(dp), err_spec,
                                  P(), P(), P(), P(), P(), P()),
                        out_specs=(P(), P(), P(), err_spec, P(), P()),
                        axis_names=axes, check_vma=False,
                    )(g, err_in, state.params, state.opt.m,
                      state.opt.v, w, lr_step, lr)

            loss = weighting.finalize(o, w)
            metrics = {"loss": loss, "weight": w, "grad_norm": gnorm,
                       "lr": lr}
            if ocfg.name == "lamb":
                metrics["trust_ratio"] = trust
            new_state = TrainState(
                params=new_params,
                opt=adam.AdamState(step=lr_step, m=new_m, v=new_v),
                err=new_err if use_err else state.err)
            return new_state, metrics

    canonical = tcfg.het.weighting == "canonical"

    def canonical_step(state: TrainState, batch: Dict
                       ) -> Tuple[TrainState, Dict]:
        """Order-canonical executor (core/weighting.py), now a real
        train-step mode instead of bench-only: per-row vmapped grads
        summed along the global-row axis with ONE fixed reduction tree.
        The row->rank partition drops out of the float math entirely,
        so two runs consuming the same global rows are bit-identical
        whatever capacity replans happened in between — provided the
        sampler emits rows in canonical global order
        (HetSampler(canonical_order=True))."""
        def row_loss(p, b):
            return model.loss_fn(p, b,
                                 label_smoothing=tcfg.label_smoothing)

        (o_r, w_r), g_r = weighting.per_row_values(
            row_loss, state.params, batch)
        loss, grads, _, w = weighting.canonical_aggregate(o_r, w_r, g_r)
        lr = schedules.learning_rate(ocfg, state.opt.step + 1)
        opt_apply = (lamb.apply_update if ocfg.name == "lamb"
                     else adam.apply_update)
        params, opt, met = opt_apply(state.params, grads,
                                     state.opt, ocfg, lr)
        metrics = {"loss": loss, "weight": w, **met}
        return TrainState(params=params, opt=opt, err=state.err), metrics

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if canonical:
            return canonical_step(state, batch)
        if overlap:
            return overlap_step(state, batch)
        if hier:
            if compat.NATIVE_MANUAL_COLLECTIVES:
                pspecs_in = state_specs(model, tcfg, mesh).params

                def pod_local(params, b, err):
                    g, o, w = compute_grads(params, b)
                    # inside the partially-manual region XLA's sharding
                    # propagation can lose the (data, model) layout of
                    # the gradients; re-pin them to the param specs so
                    # the pod exchange moves shards, not replicated
                    # leaves
                    g = jax.tree.map(
                        lambda gr, s: jax.lax.with_sharding_constraint(
                            gr, s),
                        g, pspecs_in)
                    g, ne = apply_pod_reduce(g, err)
                    return g, jax.lax.psum(o, "pod"), \
                        jax.lax.psum(w, "pod"), ne

                grads, o, w, new_err = compat.shard_map(
                    pod_local, mesh=mesh,
                    in_specs=(P(), P("pod"), P("pod") if use_err
                              else P()),
                    out_specs=(P(), P(), P(), P("pod") if use_err
                               else P()),
                    axis_names={"pod"}, check_vma=False,
                )(state.params, batch, state.err)
            else:
                g, o, w = vmapped_rank_grads(state.params, batch, n_pods,
                                             P("pod", "data"))

                def pod_reduce(gl, err):
                    return apply_pod_reduce(
                        jax.tree.map(lambda a: a[0], gl), err)

                grads, new_err = compat.shard_map(
                    pod_reduce, mesh=mesh,
                    in_specs=(P("pod"), P("pod") if use_err else P()),
                    out_specs=(P(), P("pod") if use_err else P()),
                    axis_names={"pod"}, check_vma=False,
                )(g, state.err)
        elif bucketed_ar:
            axis = dp if len(dp) > 1 else dp[0]

            def reduce_buckets(g):
                out, _ = _reduce_bucketed(g, None, axis=axis,
                                          axis_size=n_dp,
                                          compress="none", layout=layout,
                                          impl=q_impl)
                return out

            if compat.NATIVE_MANUAL_COLLECTIVES:
                def dp_local(params, b):
                    g, o, w = compute_grads(params, b)
                    return reduce_buckets(g), jax.lax.psum(o, dp), \
                        jax.lax.psum(w, dp)

                grads, o, w = compat.shard_map(
                    dp_local, mesh=mesh,
                    in_specs=(P(), P(dp)),
                    out_specs=(P(), P(), P()),
                    axis_names=set(dp), check_vma=False,
                )(state.params, batch)
            else:
                g, o, w = vmapped_rank_grads(state.params, batch, n_dp,
                                             P(dp))
                grads = compat.shard_map(
                    lambda gl: reduce_buckets(
                        jax.tree.map(lambda a: a[0], gl)),
                    mesh=mesh, in_specs=P(dp), out_specs=P(),
                    axis_names=set(dp), check_vma=False,
                )(g)
            new_err = state.err
        else:
            grads, o, w = compute_grads(state.params, batch)
            new_err = state.err
        loss = weighting.finalize(o, w)
        grads = weighting.scale_grads(grads, w)
        lr = schedules.learning_rate(ocfg, state.opt.step + 1)
        opt_apply = (lamb.apply_update if ocfg.name == "lamb"
                     else adam.apply_update)
        params, opt, met = opt_apply(state.params, grads,
                                     state.opt, ocfg, lr)
        metrics = {"loss": loss, "weight": w, **met}
        return TrainState(params=params, opt=opt, err=new_err), metrics

    specs = state_specs(model, tcfg, mesh)
    bspecs = shr.batch_specs(cfg, mesh, tcfg.shape.global_batch)
    return jax.jit(
        step,
        in_shardings=(shr.named(mesh, specs), shr.named(mesh, bspecs)),
        out_shardings=(shr.named(mesh, specs), None),
        donate_argnums=(0,),
    )


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------


def build_prefill_step(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def prefill(params, inputs):
        return model.prefill(params, inputs, ctx, max_len=shape.seq_len)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    dp = mesh_dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if b % dp_size(mesh) == 0 else None
    in_spec = (P(bspec, None, None) if cfg.frontend != "token"
               else P(bspec, None))
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(cfg, cache_shape, mesh, b)
    logit_spec = shr.fit_spec((b, cfg.vocab_size), P(bspec, "model"), mesh)
    return jax.jit(
        prefill,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, in_spec)),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
    )


def build_decode_step(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def decode(params, tokens, cache, pos):
        return model.decode(params, tokens, cache, pos, ctx)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    dp = mesh_dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if b % dp_size(mesh) == 0 else None
    tok_spec = (P(bspec, None) if cfg.frontend != "token" else P(bspec))
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(cfg, cache_shape, mesh, b)
    logit_spec = shr.fit_spec((b, cfg.vocab_size), P(bspec, "model"), mesh)
    return jax.jit(
        decode,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, tok_spec),
                      shr.named(mesh, cspecs), None),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
        donate_argnums=(2,),
    )


# --------------------------------------------------------------------------
# paged serving steps (continuous batching, repro.serve)
# --------------------------------------------------------------------------


def serve_batch_spec(batch: int, mesh: Mesh, what: str):
    """DP batch spec for a serving step — warns LOUDLY on fallback.

    When ``batch`` is not divisible by the DP extent the arrays are
    fully replicated: every rank embeds/unembeds the whole batch and
    the DP axes do no work. That is a silent multi-x serving-throughput
    loss, so it is worth a warning, not a comment (the old static
    driver fell back without a word). Pick batch/slots as a multiple
    of prod(devices[:-1]) to shard.

    Once-per-build contract: this runs ONLY inside
    ``build_paged_prefill_step`` / ``build_paged_decode_step`` (outside
    the jitted functions they return), so the warning fires once per
    step build, never once per decode step — a serve loop is thousands
    of steps and a per-step warning would bury the log. Pinned by
    tests/test_serve.py::test_serve_batch_spec_warns_once_per_build.
    """
    dp = mesh_dp_axes(mesh)
    if batch % dp_size(mesh) == 0:
        return dp
    logger.warning(
        "%s batch %d is not divisible by the DP extent %d of mesh %s — "
        "falling back to FULLY-REPLICATED batch sharding (every rank "
        "computes the whole batch; data-parallel ranks add no serving "
        "throughput). Use a batch that is a multiple of the DP extent.",
        what, batch, dp_size(mesh), tuple(mesh.shape.items()))
    return None


def build_paged_prefill_step(model: Model, mesh: Mesh, layout,
                             bucket_len: int, batch: int):
    """Jit one prefill bucket: (params, prompts (Bp, Lb), lens (Bp,),
    paged_cache, block_tables (Bp, MB)) -> (logits (Bp, V), cache).

    The pool cache is donated (argnum 3): prefill scatters into it in
    place instead of copying the whole pool per admitted group.
    """
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def prefill(params, prompts, lens, cache, tables):
        return model.prefill_paged(params, prompts, lens, cache, tables,
                                   ctx)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    bspec = serve_batch_spec(batch, mesh, "prefill")
    cache_shape = jax.eval_shape(
        functools.partial(model.init_paged_cache, layout))
    cspecs = shr.paged_cache_specs(cfg, cache_shape, mesh)
    logit_spec = shr.fit_spec((batch, cfg.vocab_size), P(bspec, "model"),
                              mesh)
    return jax.jit(
        prefill,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, P(bspec, None)),
                      NamedSharding(mesh, P(bspec)),
                      shr.named(mesh, cspecs),
                      NamedSharding(mesh, P(bspec, None))),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
        donate_argnums=(3,),
    )


def build_paged_decode_step(model: Model, mesh: Mesh, layout,
                            slots: int):
    """Jit the continuous decode step: (params, tokens (D,), paged_cache,
    block_tables (D, MB), kv_lens (D,)) -> (logits (D, V), cache).

    One fixed shape for the whole serve loop — per-sequence depth lives
    in ``kv_lens``, membership in the block tables — so the engine can
    assert the function never retraces. The pool is donated (argnum 2):
    decode updates it in place, no per-step full-cache copy.
    """
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def decode(params, tokens, cache, tables, kv_lens):
        return model.decode_paged(params, tokens, cache, tables, kv_lens,
                                  ctx)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    bspec = serve_batch_spec(slots, mesh, "decode")
    cache_shape = jax.eval_shape(
        functools.partial(model.init_paged_cache, layout))
    cspecs = shr.paged_cache_specs(cfg, cache_shape, mesh)
    logit_spec = shr.fit_spec((slots, cfg.vocab_size), P(bspec, "model"),
                              mesh)
    return jax.jit(
        decode,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, P(bspec)),
                      shr.named(mesh, cspecs),
                      NamedSharding(mesh, P(bspec, None)),
                      NamedSharding(mesh, P(bspec))),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
        donate_argnums=(2,),
    )


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, zero allocation)
# --------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model,
                kind: Optional[str] = None) -> Dict[str, Any]:
    """Stand-ins for every model input of one (arch x shape) cell.

    train  : packed batch {"inputs","labels","weights"}
    prefill: {"inputs"}
    decode : {"tokens", "cache", "pos"} — one new token against a
             seq_len-deep cache (the assigned decode_* semantics).
    """
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    stub = cfg.frontend != "token"
    if kind == "train":
        inp = (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b, s), i32))
        return {"inputs": inp,
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "weights": jax.ShapeDtypeStruct((b, s), f32)}
    if kind == "prefill":
        inp = (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b, s), i32))
        return {"inputs": inp}
    if kind == "decode":
        cache = jax.eval_shape(functools.partial(model.init_cache, b, s))
        tok = (jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b,), i32))
        return {"tokens": tok, "cache": cache,
                "pos": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(kind)
