"""Step builders: jitted train / prefill / decode with explicit shardings.

``build_train_step`` assembles the full HetSeq step:
  1. weighted objective over the packed (dummy-padded) global batch —
     per-token weights make heterogeneous capacity exact (core M1/M3);
  2. optional gradient accumulation scan (core M4);
  3. gradient reduction:
       * "allreduce"    — paper-faithful: XLA's automatic reduction from
         the shardings (FSDP => reduce-scatter + all-gather);
       * "hierarchical" — beyond-paper: params replicated over "pod",
         FSDP over "data"; in-pod reduction stays automatic (ICI), the
         cross-pod leg is an explicit shard_map(axis_names={"pod"})
         collective, optionally int8-compressed with error feedback;
  4. AdamW update (optimizer state sharded like params = ZeRO-1).

``input_specs`` provides ShapeDtypeStruct stand-ins for every cell of
the (architecture x shape) grid — the dry-run lowers against these, no
allocation ever happens.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import accumulate as acc
from repro.core import weighting
from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import ref as q_ref
from repro.launch import sharding as shr
from repro.launch.mesh import dp_axes as mesh_dp_axes, dp_size, tp_axis
from repro.models.blocks import ParallelCtx
from repro.models.model import Model
from repro.optim import adam, lamb, schedules


def make_parallel_ctx(mesh: Optional[Mesh]) -> ParallelCtx:
    if mesh is None:
        return ParallelCtx()
    return ParallelCtx(mesh=mesh, dp_axes=mesh_dp_axes(mesh),
                       tp_axis=tp_axis(mesh))


# --------------------------------------------------------------------------
# train state
# --------------------------------------------------------------------------


class TrainState(NamedTuple):
    params: Any
    opt: adam.AdamState
    err: Any                       # error-feedback pytree or () when unused


def _err_enabled(tcfg: TrainConfig, mesh: Mesh) -> bool:
    return (tcfg.het.grad_reduction == "hierarchical"
            and tcfg.het.compression != "none"
            and tcfg.het.error_feedback
            and "pod" in mesh.axis_names)


def state_shapes(model: Model, tcfg: TrainConfig, mesh: Mesh):
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(
        functools.partial(adam.init_state, cfg=tcfg.optimizer), params_shape)
    if _err_enabled(tcfg, mesh):
        pods = mesh.shape["pod"]
        err_shape = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((pods,) + p.shape, jnp.float32),
            params_shape)
    else:
        err_shape = ()
    return TrainState(params=params_shape, opt=opt_shape, err=err_shape)


def state_specs(model: Model, tcfg: TrainConfig, mesh: Mesh) -> TrainState:
    shapes = state_shapes(model, tcfg, mesh)
    hier = tcfg.het.grad_reduction == "hierarchical"
    pspecs = shr.param_specs(model.cfg, shapes.params, mesh)
    if hier and "pod" in mesh.axis_names:
        # hierarchical mode: params replicated across pods (FSDP = data
        # only) so the cross-pod gradient leg is ours to schedule

        def strip_pod(spec: P) -> P:
            out = []
            for ax in spec:
                if isinstance(ax, tuple):
                    kept = tuple(a for a in ax if a != "pod")
                    out.append(kept if kept else None)
                else:
                    out.append(None if ax == "pod" else ax)
            return P(*out)

        pspecs = jax.tree.map(strip_pod, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        # token-embedding gathers with a sharded vocab dim hit an XLA
        # SPMD-partitioner bug inside partially-manual regions; shard the
        # table on d_model only (gather pass-through dim) in this mode
        if isinstance(pspecs, dict) and "embed" in pspecs:
            tp = "model" if "model" in mesh.axis_names else None
            vshape = shapes.params["embed"].shape
            pspecs = dict(pspecs)
            pspecs["embed"] = shr.fit_spec(vshape, P(None, tp), mesh)
    ospecs = adam.AdamState(step=P(), m=pspecs, v=pspecs)
    if shapes.err == ():
        especs: Any = ()
    else:
        especs = jax.tree.map(lambda s: P("pod", *s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    return TrainState(params=pspecs, opt=ospecs, err=especs)


def init_train_state(model: Model, tcfg: TrainConfig, mesh: Mesh,
                     key) -> TrainState:
    """Initialize on-device with the right shardings (M8: same init
    everywhere — a single global RNG key IS the broadcast)."""
    specs = state_specs(model, tcfg, mesh)
    shapes = state_shapes(model, tcfg, mesh)

    def init(k):
        params = model.init_params(k)
        opt = adam.init_state(params, tcfg.optimizer)
        if shapes.err == ():
            err: Any = ()
        else:
            err = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), shapes.err)
        return TrainState(params=params, opt=opt, err=err)

    with jax.set_mesh(mesh):
        return jax.jit(init, out_shardings=shr.named(mesh, specs))(key)


def init_params_sharded(model: Model, mesh: Mesh, key):
    """Initialize bare params with the production shardings (serving)."""
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(model.cfg, params_shape, mesh)
    with jax.set_mesh(mesh):
        return jax.jit(model.init_params,
                       out_shardings=shr.named(mesh, pspecs))(key)


def init_cache_sharded(model: Model, shape: ShapeConfig, mesh: Mesh):
    """Zero cache with the decode-step shardings."""
    b = shape.global_batch
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(model.cfg, cache_shape, mesh, b)
    with jax.set_mesh(mesh):
        return jax.jit(functools.partial(model.init_cache, b,
                                         shape.seq_len),
                       out_shardings=shr.named(mesh, cspecs))()


# --------------------------------------------------------------------------
# gradient reduction modes
# --------------------------------------------------------------------------


def _quant_lastdim(x: jnp.ndarray, block: int):
    """Blockwise int8 quantization along the LAST dim only.

    Unlike the flatten-everything kernel wrapper, this preserves the
    sharding of every other dim — flattening a (data, model)-sharded
    matrix forces XLA to all-gather it before the reshape (measured:
    38 GB of replicated gradient copies in the hier step).
    """
    last = x.shape[-1]
    bs = min(block, last)
    pad = (-last) % bs
    if pad:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
    nb = x.shape[-1] // bs
    blocks = x.reshape(*x.shape[:-1], nb, bs)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0], last


def _dequant_lastdim(q: jnp.ndarray, scale: jnp.ndarray, last: int):
    deq = q.astype(jnp.float32) * scale[..., None]
    deq = deq.reshape(*deq.shape[:-2], -1)
    return deq[..., :last]


def _cross_pod_reduce(grads: Any, err: Any, compress: str,
                      block_size: int = 256) -> Tuple[Any, Any]:
    """Inside shard_map(manual={"pod"}): reduce grads across pods.

    grads: this pod's gradient contribution (auto-sharded over data).
    err:   (1, *shape) this pod's persistent error-feedback state.
    """
    def leaf(g, e):
        if compress == "none":
            return jax.lax.psum(g, "pod"), e
        gf = g.astype(jnp.float32)
        if gf.ndim == 1:
            gf = gf[None]
            squeeze = True
        else:
            squeeze = False
        corrected = gf + (e.reshape(gf.shape).astype(jnp.float32)
                          if e is not None else 0.0)
        q, s, last = _quant_lastdim(corrected, block_size)
        deq_local = _dequant_lastdim(q, s, last)
        new_e = ((corrected - deq_local).reshape(e.shape)
                 if e is not None else e)
        # int8 payload + per-block scales are what cross the DCN link;
        # gathered along a NEW leading pod axis (all shardings preserved)
        q_all = jax.lax.all_gather(q, "pod")          # (pods, ..., nb, bs)
        s_all = jax.lax.all_gather(s, "pod")          # (pods, ..., nb)
        deq = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None],
                      axis=0)
        out = deq.reshape(*deq.shape[:-2], -1)[..., :last]
        if squeeze:
            out = out[0]
        return out.astype(g.dtype), new_e

    if err == ():
        outs = jax.tree.map(lambda g: leaf(g, None)[0], grads)
        return outs, ()
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------


def build_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh
                     ) -> Callable[[TrainState, Dict], Tuple[TrainState,
                                                             Dict]]:
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)
    ocfg = tcfg.optimizer
    accum = max(1, tcfg.het.accum_steps)
    hier = (tcfg.het.grad_reduction == "hierarchical"
            and "pod" in mesh.axis_names)
    compress = tcfg.het.compression if hier else "none"
    n_dp = dp_size(mesh)

    # inside the pod-manual region the "pod" axis must not appear in
    # sharding constraints — the inner context is data/model only
    inner_ctx = (ParallelCtx(mesh=mesh, dp_axes=("data",),
                             tp_axis=tp_axis(mesh)) if hier else ctx)
    inner_dp = n_dp // mesh.shape["pod"] if hier else n_dp

    def compute_grads(params, batch):
        """Returns (grad_of_sums, obj_sum, weight_sum) — unscaled."""
        def objective(p, b):
            o, w, _ = model.loss_fn(p, b, inner_ctx)
            return o, w

        grad_fn = jax.value_and_grad(objective, has_aux=True)
        if accum == 1:
            (o, w), g = grad_fn(params, batch)
            return g, o, w
        mbs = acc.split_microbatches(batch, accum, num_ranks=inner_dp)

        def body(carry, mb):
            g_acc, o_acc, w_acc = carry
            (o, w), g = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 g_acc, g)
            return (g_acc, o_acc + o, w_acc + w), None

        # accumulation carry dtype: fp32, except when params are stored
        # bf16 (arctic/deepseek giants) where an fp32 carry alone would
        # blow the 16 GB budget — bf16 carry, documented in EXPERIMENTS
        def carry_dtype(p):
            return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, carry_dtype(p)), params)
        (g, o, w), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), mbs)
        return g, o, w

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if hier:
            pspecs_in = state_specs(model, tcfg, mesh).params

            def pod_local(params, b, err):
                g, o, w = compute_grads(params, b)
                # inside the partially-manual region XLA's sharding
                # propagation can lose the (data, model) layout of the
                # gradients; re-pin them to the param specs so the pod
                # exchange moves shards, not replicated leaves
                g = jax.tree.map(
                    lambda gr, s: jax.lax.with_sharding_constraint(gr, s),
                    g, pspecs_in)
                g, new_err = _cross_pod_reduce(g, err, compress)
                return g, jax.lax.psum(o, "pod"), jax.lax.psum(w, "pod"), \
                    new_err

            grads, o, w, new_err = jax.shard_map(
                pod_local, mesh=mesh,
                in_specs=(P(), P("pod"), P("pod") if state.err != ()
                          else P()),
                out_specs=(P(), P(), P(), P("pod") if state.err != ()
                           else P()),
                axis_names={"pod"}, check_vma=False,
            )(state.params, batch, state.err)
        else:
            grads, o, w = compute_grads(state.params, batch)
            new_err = state.err
        loss = weighting.finalize(o, w)
        grads = weighting.scale_grads(grads, w)
        lr = schedules.learning_rate(ocfg, state.opt.step + 1)
        opt_apply = (lamb.apply_update if ocfg.name == "lamb"
                     else adam.apply_update)
        params, opt, met = opt_apply(state.params, grads,
                                     state.opt, ocfg, lr)
        metrics = {"loss": loss, "weight": w, **met}
        return TrainState(params=params, opt=opt, err=new_err), metrics

    specs = state_specs(model, tcfg, mesh)
    bspecs = shr.batch_specs(cfg, mesh, tcfg.shape.global_batch)
    return jax.jit(
        step,
        in_shardings=(shr.named(mesh, specs), shr.named(mesh, bspecs)),
        out_shardings=(shr.named(mesh, specs), None),
        donate_argnums=(0,),
    )


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------


def build_prefill_step(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def prefill(params, inputs):
        return model.prefill(params, inputs, ctx, max_len=shape.seq_len)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    dp = mesh_dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if b % dp_size(mesh) == 0 else None
    in_spec = (P(bspec, None, None) if cfg.frontend != "token"
               else P(bspec, None))
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(cfg, cache_shape, mesh, b)
    logit_spec = shr.fit_spec((b, cfg.vocab_size), P(bspec, "model"), mesh)
    return jax.jit(
        prefill,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, in_spec)),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
    )


def build_decode_step(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    ctx = make_parallel_ctx(mesh)

    def decode(params, tokens, cache, pos):
        return model.decode(params, tokens, cache, pos, ctx)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = shr.param_specs(cfg, params_shape, mesh)
    dp = mesh_dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if b % dp_size(mesh) == 0 else None
    tok_spec = (P(bspec, None) if cfg.frontend != "token" else P(bspec))
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cspecs = shr.cache_specs(cfg, cache_shape, mesh, b)
    logit_spec = shr.fit_spec((b, cfg.vocab_size), P(bspec, "model"), mesh)
    return jax.jit(
        decode,
        in_shardings=(shr.named(mesh, pspecs),
                      NamedSharding(mesh, tok_spec),
                      shr.named(mesh, cspecs), None),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shr.named(mesh, cspecs)),
        donate_argnums=(2,),
    )


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, zero allocation)
# --------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model,
                kind: Optional[str] = None) -> Dict[str, Any]:
    """Stand-ins for every model input of one (arch x shape) cell.

    train  : packed batch {"inputs","labels","weights"}
    prefill: {"inputs"}
    decode : {"tokens", "cache", "pos"} — one new token against a
             seq_len-deep cache (the assigned decode_* semantics).
    """
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    stub = cfg.frontend != "token"
    if kind == "train":
        inp = (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b, s), i32))
        return {"inputs": inp,
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "weights": jax.ShapeDtypeStruct((b, s), f32)}
    if kind == "prefill":
        inp = (jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b, s), i32))
        return {"inputs": inp}
    if kind == "decode":
        cache = jax.eval_shape(functools.partial(model.init_cache, b, s))
        tok = (jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
               if stub else jax.ShapeDtypeStruct((b,), i32))
        return {"tokens": tok, "cache": cache,
                "pos": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(kind)
