"""End-to-end heterogeneous training driver.

Wires every subsystem: synthetic/sharded data -> capacity plan ->
het sampler + prefetch loader -> jitted SPMD train step (weighted DP,
optional hierarchical/compressed reduction) -> straggler monitor ->
checkpointing -> elastic restart.

Elastic restart (core/elastic.py regime 2): when soft replanning cannot
absorb a membership change (``RemeshRequired``), the driver maps dead
DP ranks to lost pods, asks ``elastic.plan_remesh`` for the surviving
topology + capacity plan, rebuilds the mesh/step/loader, and restores
the latest checkpoint into the new layout — ``CheckpointManager.restore``
repacks packed optimizer state across bucket grids and mesh sizes
(checkpoint/repack.py), and ``elastic.validate_resume_equivalence``
verifies the old and new plans consume the identical global record
stream before training continues at the saved data-stream position.

Runs on anything: real TPU pods (production mesh) or this CPU container
(--devices data,model uses host devices; --smoke uses reduced configs).
Fault injection goes through the deterministic chaos engine
(core/chaos.py): ``--chaos <schedule.json|preset>`` scripts slowdowns,
rank/pod kills, flaky reports and checkpoint-IO failures, whose modeled
per-rank step times feed the straggler monitor (replacing the
undifferentiated host clock of single-process emulation) — slow ranks
shed rows via soft replans, dead ranks escalate to the elastic re-mesh.
``--kill-pod P@S`` is kept as a back-compat alias for a one-entry kill
schedule and exercises the full detect -> replan -> remesh ->
repacked-resume path end to end.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --global-batch 16 --seq-len 64 \
      --capacities 2,1,1 --devices 4,1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base as cfgbase
from repro.configs.base import (HetConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import capacity as cap
from repro.core import chaos, elastic
from repro.core.straggler import RemeshRequired, StragglerMonitor
from repro.data.dataset import ShardedDataset
from repro.data.loader import PrefetchLoader
from repro.data.sampler import HetSampler
from repro.data.synthetic import build_synthetic_corpus
from repro.launch import steps as steps_mod
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.sharding import batch_specs, named
from repro.models.model import build_model


def build_everything(args):
    cfg = (cfgbase.smoke_config(args.arch) if args.smoke
           else cfgbase.resolve(args.arch))
    if getattr(args, "no_scan_layers", False):
        # unrolled layer stack — required by --overlap backward (the
        # staged layer-by-layer backward is an unrolled program)
        cfg = dataclasses.replace(cfg, scan_layers=False)
    model = build_model(cfg)

    dshape = tuple(int(x) for x in args.devices.split(","))
    n_needed = int(np.prod(dshape))
    if n_needed > len(jax.devices()):
        raise SystemExit(
            f"need {n_needed} devices, have {len(jax.devices())}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_needed}")
    axes = ("data", "model") if len(dshape) == 2 else ("pod", "data",
                                                       "model")
    mesh = jax.make_mesh(dshape, axes)

    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    tcfg = TrainConfig(
        model=cfg, shape=shape,
        het=HetConfig(
            capacities=tuple(float(c) for c in args.capacities.split(","))
            if args.capacities else (),
            weighting=args.weighting,
            grad_reduction=args.grad_reduction,
            compression=args.compression,
            bucket_mb=args.bucket_mb,
            overlap=args.overlap,
            accum_steps=args.accum,
            replan_interval=args.replan_interval,
            pipeline_stages=args.pipeline_stages,
            pipeline_schedule=args.pipeline_schedule),
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  warmup_steps=args.warmup,
                                  total_steps=args.steps,
                                  schedule=args.schedule),
        seed=args.seed, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    return cfg, model, mesh, tcfg


def make_plan(tcfg: TrainConfig, mesh) -> cap.CapacityPlan:
    n_dp = dp_size(mesh)
    caps = tcfg.het.capacities or tuple([1.0] * n_dp)
    if len(caps) != n_dp:
        raise SystemExit(f"--capacities needs {n_dp} entries (dp size)")
    return cap.plan_capacities(tcfg.shape.global_batch, caps,
                               headroom=1.25,
                               round_buffer_to=max(tcfg.het.accum_steps,
                                                   1))


def topology_from_mesh(mesh) -> elastic.MeshTopology:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return elastic.MeshTopology(pods=shape.get("pod", 1),
                                data_per_pod=shape.get("data", 1),
                                model=shape.get("model", 1))


def mesh_for_topology(topo: elastic.MeshTopology):
    """Mesh over the first N live devices (re-mesh uses a device subset
    — on a real fleet the coordinator would hand back the survivors)."""
    shape = topo.mesh_shape()
    n = int(np.prod(shape))
    if n > len(jax.devices()):
        raise SystemExit(f"re-mesh needs {n} devices, "
                         f"have {len(jax.devices())}")
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, topo.mesh_axes())


def _parse_kill(spec: str) -> Optional[Tuple[int, int]]:
    """'P@S' -> (pod P, from global step S). Back-compat alias: becomes
    a one-entry ``chaos.kill(pod=P, step=S)`` schedule."""
    if not spec:
        return None
    pod, at = spec.split("@")
    return int(pod), int(at)


def build_chaos_engine(args, tcfg: TrainConfig, mesh,
                       topo: elastic.MeshTopology) -> chaos.ChaosEngine:
    """Resolve --chaos (+ the --kill-pod alias) into one engine — the
    single fault-injection path for the driver."""
    n_dp = dp_size(mesh)
    schedule = chaos.ChaosSchedule(seed=tcfg.seed)
    if args.chaos:
        try:
            schedule = chaos.load_schedule(
                args.chaos, num_ranks=n_dp,
                data_per_pod=topo.data_per_pod,
                total_steps=args.steps, seed=tcfg.seed)
        except (ValueError, OSError) as e:
            raise SystemExit(f"[train] --chaos: {e}") from e
    kill = _parse_kill(args.kill_pod)
    if kill is not None:
        schedule = schedule.with_events(
            chaos.kill(pod=kill[0], step=kill[1]))
    try:
        return chaos.ChaosEngine(
            schedule, num_ranks=n_dp, data_per_pod=topo.data_per_pod,
            speeds=tcfg.het.capacities or None)
    except ValueError as e:
        raise SystemExit(f"[train] {e}") from e


def train(args) -> Dict[str, float]:
    cfg, model, mesh, tcfg = build_everything(args)
    topo = topology_from_mesh(mesh)
    plan = make_plan(tcfg, mesh)
    print(f"[train] {cfg.name}: {cfg.param_count():,} params, mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, plan rows "
          f"{plan.rows_per_rank.tolist()} buffer {plan.buffer_rows} "
          f"(efficiency {plan.efficiency():.2f})")
    # resolve fault injection before --dry-run exits so a documented
    # --chaos preset / schedule (and --kill-pod target) is validated by
    # the README docs smoke
    engine = build_chaos_engine(args, tcfg, mesh, topo)
    if engine.schedule.events:
        kinds = sorted({ev.kind for ev in engine.schedule.events})
        print(f"[train] chaos: {len(engine.schedule.events)} event(s) "
              f"{kinds} (seed {engine.schedule.seed})")
    if args.dry_run:
        # validate the full config stack (the same checks
        # build_train_step runs) and stop before any compilation or
        # data generation — the README quickstart smoke in
        # benchmarks/run.py --quick executes every documented command
        # this way, so a renamed flag or an invalid documented config
        # fails the quick tier loudly
        steps_mod.validate_train_config(model, tcfg, mesh)
        print(f"[train] dry-run ok: grad_reduction="
              f"{tcfg.het.grad_reduction} overlap={tcfg.het.overlap} "
              f"bucket_mb={tcfg.het.bucket_mb} "
              f"compression={tcfg.het.compression} "
              f"accum={tcfg.het.accum_steps} "
              f"optimizer={tcfg.optimizer.name} "
              f"scan_layers={cfg.scan_layers} "
              f"pipeline_stages={tcfg.het.pipeline_stages}")
        return {"steps": 0, "wall_s": 0.0}

    corpus = build_synthetic_corpus(
        args.data_dir, num_seqs=max(4 * plan.global_rows, 256),
        seq_len=args.seq_len + 1, vocab=cfg.vocab_size,
        rows_per_shard=64, seed=tcfg.seed)
    ds = ShardedDataset(corpus)
    mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep,
                            fault_hook=engine.ckpt_fault_hook())

    def build_runtime(mesh, plan):
        """Everything that depends on the mesh / plan (rebuilt on
        re-mesh)."""
        with compat.set_mesh(mesh):
            step_fn = steps_mod.build_train_step(model, tcfg, mesh)
        canonical = tcfg.het.weighting == "canonical"
        sampler = HetSampler(ds, plan, seed=tcfg.seed,
                             canonical_order=canonical)
        loader = PrefetchLoader(sampler, depth=args.prefetch)
        # canonical batches are global-row-ordered (global_rows rows,
        # plan-independent); packed batches are rank-buffer-ordered
        # (padded_rows rows)
        batch_rows = plan.global_rows if canonical else plan.padded_rows
        bspecs = named(mesh, batch_specs(cfg, mesh, batch_rows))
        fmt = steps_mod.checkpoint_format(model, tcfg, mesh)
        return step_fn, sampler, loader, bspecs, fmt

    def restore_state(mesh, plan):
        """Repacked restore: the template carries THIS config's layout;
        the manager translates whatever the checkpoint holds into it."""
        template = steps_mod.state_shapes(model, tcfg, mesh)
        host, meta = mgr.restore(template,
                                 expected_overlap=tcfg.het.overlap)
        saved_plan = meta.get("plan")
        if saved_plan is not None and not \
                elastic.validate_resume_equivalence(saved_plan, plan):
            raise SystemExit(
                f"[train] resume refused: checkpoint plan "
                f"(rows {list(saved_plan.rows_per_rank)}, global "
                f"{saved_plan.global_rows}) and the current plan "
                f"(rows {plan.rows_per_rank.tolist()}, global "
                f"{plan.global_rows}) consume different global record "
                f"streams")
        saved_pipe = (meta.get("format") or {}).get("pipeline")
        cur_pipe = fmt.get("pipeline")
        if saved_pipe != cur_pipe:
            def _pdesc(rec):
                if not rec:
                    return "none"
                return (f"stages={len(rec['plan']['rows_per_rank'])} "
                        f"layers={rec['plan']['rows_per_rank']}")
            # params are stored per-leaf, so the restore itself is
            # bit-exact under any stage plan — log, never adapt
            print(f"[train] restore: pipeline stage plan changed: "
                  f"{_pdesc(saved_pipe)} -> {_pdesc(cur_pipe)}")
        specs = steps_mod.state_specs(model, tcfg, mesh)
        with compat.set_mesh(mesh):
            state = jax.device_put(host, named(mesh, specs))
        stream = meta.get("stream") or {}
        position = (int(meta["step"]),
                    int(stream.get("epoch", meta.get("epoch", 0))),
                    int(stream.get("batch_in_epoch", 0)))
        return state, position

    step_fn, sampler, loader, bspecs, fmt = build_runtime(mesh, plan)
    n_dp = dp_size(mesh)
    start_step = 0
    epoch = 0
    batch_in_epoch = 0
    if args.resume and mgr.latest_step() is not None:
        state, (start_step, epoch, batch_in_epoch) = restore_state(mesh,
                                                                   plan)
        print(f"[train] resumed from step {start_step} "
              f"(epoch {epoch}, batch {batch_in_epoch})")
    else:
        with compat.set_mesh(mesh):
            state = steps_mod.init_train_state(
                model, tcfg, mesh, jax.random.PRNGKey(tcfg.seed))

    monitor = StragglerMonitor(num_ranks=n_dp,
                               ema_decay=tcfg.het.straggler_ema,
                               replan_interval=tcfg.het.replan_interval)

    def save_meta():
        return {"epoch": epoch, "seed": tcfg.seed, "plan": plan,
                "format": fmt,
                "stream": {"epoch": epoch,
                           "batch_in_epoch": batch_in_epoch}}

    step = start_step
    losses = []
    t_start = time.time()
    body_raised = False
    try:
        while step < args.steps:
            try:
                with compat.set_mesh(mesh):
                    while step < args.steps:
                        consumed = 0
                        for raw in loader.iter_epoch(epoch):
                            consumed += 1
                            if consumed <= batch_in_epoch:
                                continue      # resume mid-epoch: skip
                            if step >= args.steps:
                                break
                            # hetsampler pads the *labels*: inputs are
                            # the shifted view
                            batch = {
                                "inputs": jnp.asarray(
                                    raw["inputs"][:, :args.seq_len]),
                                "labels": jnp.asarray(
                                    raw["labels"][:, :args.seq_len]),
                                "weights": jnp.asarray(
                                    raw["weights"][:, :args.seq_len]),
                            }
                            batch = jax.device_put(batch, bspecs)
                            t0 = time.time()
                            state, metrics = step_fn(state, batch)
                            loss = float(metrics["loss"])
                            dt = time.time() - t0
                            losses.append(loss)
                            step += 1
                            batch_in_epoch = consumed
                            # per-rank step times: on real fleets each
                            # host reports; here the chaos engine
                            # differentiates ranks from the host clock
                            # (slowdowns inflate, kills/flaky drop the
                            # report). No schedule => every rank reports
                            # the measured time.
                            monitor.observe(engine.step_times(
                                step, plan.rows_per_rank, dt))
                            if monitor.should_replan():
                                new_plan = monitor.replan(plan)
                                if new_plan.rows_per_rank.tolist() != \
                                        plan.rows_per_rank.tolist():
                                    print(f"[train] replan: rows "
                                          f"{plan.rows_per_rank.tolist()}"
                                          f" -> "
                                          f"{new_plan.rows_per_rank.tolist()}")
                                plan = new_plan
                                sampler.set_plan(plan)
                            if step % args.log_every == 0:
                                print(f"[train] step {step:5d} loss "
                                      f"{loss:.4f} ({dt * 1e3:.0f} ms)")
                            if tcfg.ckpt_every and \
                                    step % tcfg.ckpt_every == 0:
                                mgr.save(step, jax.device_get(state),
                                         meta=save_meta())
                        if step >= args.steps:
                            break
                        epoch += 1
                        batch_in_epoch = 0
            except RemeshRequired as e:
                mgr.wait()                 # flush any in-flight write
                if mgr.latest_step() is None:
                    raise SystemExit(
                        f"[train] remesh required ({e}) but no "
                        f"checkpoint exists to restart from — set "
                        f"--ckpt-every") from e
                dead = set(monitor.dead_ranks().tolist())
                dpp = topo.data_per_pod
                alive = [p for p in range(topo.pods)
                         if not all(r in dead
                                    for r in range(p * dpp,
                                                   (p + 1) * dpp))]
                caps = tcfg.het.capacities
                caps_per_pod = (
                    [float(np.mean(caps[p * dpp:(p + 1) * dpp]))
                     for p in range(topo.pods)] if caps else None)
                decision = elastic.plan_remesh(
                    topo, alive, plan.global_rows, caps_per_pod,
                    round_buffer_to=max(tcfg.het.accum_steps, 1))
                print(f"[train] remesh: {decision.reason}")
                if not decision.restart_required:
                    # every pod still has live ranks, yet soft
                    # replanning just FAILED (that is what raised
                    # RemeshRequired) — re-planning from static
                    # capacities would assign real rows to the dead
                    # ranks and loop forever. Re-mesh granularity is
                    # whole pods; escalate loudly.
                    raise SystemExit(
                        f"[train] ranks {sorted(dead)} are dead but no "
                        f"whole pod is lost, and soft replanning cannot "
                        f"absorb them ({e}); shrink the global batch or "
                        f"drain the affected pod") from e
                if not elastic.validate_resume_equivalence(plan,
                                                           decision.plan):
                    raise SystemExit(
                        "[train] remesh produced a plan that consumes "
                        "a different global record stream") from e
                topo = decision.topology
                mesh = mesh_for_topology(topo)
                plan = decision.plan
                n_dp = dp_size(mesh)
                # capacities were indexed by the OLD rank numbering —
                # after the re-mesh the survivors are renumbered, so
                # the stale list would skew any later replan; the plan
                # from plan_remesh is authoritative now. accum_steps
                # scales to preserve the per-microbatch grid across
                # the DP-width change: the resumed trajectory stays
                # bit-identical (see elastic.RemeshDecision.accum_scale).
                tcfg = dataclasses.replace(
                    tcfg, het=dataclasses.replace(
                        tcfg.het, capacities=(),
                        accum_steps=(tcfg.het.accum_steps *
                                     decision.accum_scale)))
                if decision.accum_scale > 1:
                    print(f"[train] accum_steps scaled x"
                          f"{decision.accum_scale} to preserve the "
                          f"microbatch grid")
                step_fn, sampler, loader, bspecs, fmt = build_runtime(
                    mesh, plan)
                state, (step, epoch, batch_in_epoch) = restore_state(
                    mesh, plan)
                # the rollback discards the post-checkpoint trajectory:
                # drop its loss entries so the final summary reports
                # only steps that are part of the resumed run
                del losses[max(step - start_step, 0):]
                monitor = StragglerMonitor(
                    num_ranks=n_dp, ema_decay=tcfg.het.straggler_ema,
                    replan_interval=tcfg.het.replan_interval)
                # remap surviving ranks; faults on the dead pod vanish
                # with it (mgr keeps its original ckpt fault hook so
                # transient-attempt counters survive the re-mesh)
                engine = engine.after_remesh(alive)
                print(f"[train] re-meshed to "
                      f"{dict(zip(mesh.axis_names, mesh.devices.shape))}"
                      f", resumed step {step} (epoch {epoch}, batch "
                      f"{batch_in_epoch})")
        mgr.save(step, jax.device_get(state), meta=save_meta(),
                 block=True)
    except BaseException:
        body_raised = True
        raise
    finally:
        # join the async writer on EVERY exit path (clean, SystemExit
        # from a failed remesh, any step error): the daemon thread
        # would otherwise die with the process and silently lose the
        # run's final checkpoint. On a clean exit a deferred write
        # error must PROPAGATE (the final checkpoint did not land);
        # while another exception is already unwinding, don't mask it
        # — print and let the original continue. (sys.exc_info() can't
        # make this call here: inside the except handler it reports
        # the wait error itself, so the flag is set by the body.)
        try:
            mgr.wait()
        except BaseException as werr:
            if not body_raised:
                raise
            print(f"[train] WARNING: checkpoint writer failed during "
                  f"shutdown: {werr!r}")
    wall = time.time() - t_start
    if not losses:                       # resumed an already-done run
        print(f"[train] nothing to do: checkpoint already at step "
              f"{step} >= --steps {args.steps}")
        return {"steps": step, "wall_s": wall}
    print(f"[train] done: {step - start_step} steps in {wall:.1f}s, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"steps": step, "wall_s": wall, "first_loss": losses[0],
            "last_loss": losses[-1]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--devices", default="1,1",
                    help="mesh shape: data,model or pod,data,model")
    ap.add_argument("--capacities", default="",
                    help="per-DP-rank relative capacities, e.g. 2,1,1,0")
    ap.add_argument("--weighting", default="tokens",
                    choices=list(cfgbase.WEIGHTING_MODES),
                    help="'canonical': order-canonical executor — "
                         "per-row grads summed in global-row order, "
                         "bit-identical across capacity replans (needs "
                         "plain allreduce, no overlap/compression)")
    ap.add_argument("--grad-reduction", default="allreduce",
                    choices=list(cfgbase.GRAD_REDUCTION_MODES))
    ap.add_argument("--compression", default="none",
                    choices=list(cfgbase.COMPRESSION_MODES))
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="bucketed flat-buffer reduction: bucket payload"
                         " in MiB of f32 (0 = legacy per-leaf walk)")
    ap.add_argument("--overlap", default="none",
                    choices=list(cfgbase.OVERLAP_MODES),
                    help="'buckets': double-buffered per-bucket exchange"
                         " fused with per-bucket optimizer updates,"
                         " after the backward pass; 'backward': flush"
                         " buckets DURING backprop as each layer's"
                         " grads land (also needs --no-scan-layers)."
                         " Both need an explicit --grad-reduction and"
                         " --bucket-mb > 0")
    ap.add_argument("--no-scan-layers", action="store_true",
                    help="unroll the layer stack instead of lax.scan "
                         "(required by --overlap backward and "
                         "--pipeline-stages > 1; larger HLO)")
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="split the layer stack into N contiguous "
                         "pipeline stages sized by per-pod capacity "
                         "(core/pipeline.py); needs --no-scan-layers, "
                         "--overlap none and --accum >= N (the "
                         "accumulation microbatches are the 1F1B "
                         "stream). 1 = no pipelining")
    ap.add_argument("--pipeline-schedule", default="1f1b",
                    choices=list(cfgbase.PIPELINE_MODES),
                    help="microbatch schedule for --pipeline-stages > 1:"
                         " 1f1b (warmup / steady / drain, bounded "
                         "activation memory) or gpipe (all forwards "
                         "then all backwards)")
    ap.add_argument("--dry-run", action="store_true",
                    help="build mesh/plan, validate the config, print "
                         "the summary, and exit without training")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "lamb"],
                    help="lamb = the paper's stated future work "
                         "(You et al. 2019) for large het batches")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--schedule", default="inverse_sqrt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--replan-interval", type=int, default=100,
                    help="steps between straggler capacity replans")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/hetseq_ckpt")
    ap.add_argument("--data-dir", default="/tmp/hetseq_data")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--chaos", default="",
                    help="fault injection: a schedule.json path or a "
                         "preset name "
                         f"({', '.join(sorted(chaos.PRESETS))}) — "
                         "deterministic per-rank slowdowns, rank/pod "
                         "kills, flaky reports, checkpoint-IO faults "
                         "(core/chaos.py)")
    ap.add_argument("--kill-pod", default="",
                    help="fault injection 'P@S': pod P stops reporting "
                         "from global step S (exercises the elastic "
                         "remesh restart); alias for a one-entry "
                         "--chaos kill schedule")
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
