"""End-to-end heterogeneous training driver.

Wires every subsystem: synthetic/sharded data -> capacity plan ->
het sampler + prefetch loader -> jitted SPMD train step (weighted DP,
optional hierarchical/compressed reduction) -> straggler monitor ->
checkpointing -> elastic restart.

Runs on anything: real TPU pods (production mesh) or this CPU container
(--devices data,model uses host devices; --smoke uses reduced configs).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --global-batch 16 --seq-len 64 \
      --capacities 2,1,1 --devices 4,1
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base as cfgbase
from repro.configs.base import (HetConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import capacity as cap
from repro.core.straggler import RemeshRequired, StragglerMonitor
from repro.data.dataset import ShardedDataset
from repro.data.loader import PrefetchLoader
from repro.data.sampler import HetSampler
from repro.data.synthetic import build_synthetic_corpus
from repro.launch import steps as steps_mod
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.sharding import batch_specs, named
from repro.models.model import build_model


def build_everything(args):
    cfg = (cfgbase.smoke_config(args.arch) if args.smoke
           else cfgbase.resolve(args.arch))
    model = build_model(cfg)

    dshape = tuple(int(x) for x in args.devices.split(","))
    n_needed = int(np.prod(dshape))
    if n_needed > len(jax.devices()):
        raise SystemExit(
            f"need {n_needed} devices, have {len(jax.devices())}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_needed}")
    axes = ("data", "model") if len(dshape) == 2 else ("pod", "data",
                                                       "model")
    mesh = jax.make_mesh(dshape, axes)

    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    tcfg = TrainConfig(
        model=cfg, shape=shape,
        het=HetConfig(
            capacities=tuple(float(c) for c in args.capacities.split(","))
            if args.capacities else (),
            grad_reduction=args.grad_reduction,
            compression=args.compression,
            bucket_mb=args.bucket_mb,
            overlap=args.overlap,
            accum_steps=args.accum),
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  warmup_steps=args.warmup,
                                  total_steps=args.steps,
                                  schedule=args.schedule),
        seed=args.seed, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    return cfg, model, mesh, tcfg


def make_plan(tcfg: TrainConfig, mesh) -> cap.CapacityPlan:
    n_dp = dp_size(mesh)
    caps = tcfg.het.capacities or tuple([1.0] * n_dp)
    if len(caps) != n_dp:
        raise SystemExit(f"--capacities needs {n_dp} entries (dp size)")
    return cap.plan_capacities(tcfg.shape.global_batch, caps,
                               headroom=1.25,
                               round_buffer_to=max(tcfg.het.accum_steps,
                                                   1))


def train(args) -> Dict[str, float]:
    cfg, model, mesh, tcfg = build_everything(args)
    n_dp = dp_size(mesh)
    plan = make_plan(tcfg, mesh)
    print(f"[train] {cfg.name}: {cfg.param_count():,} params, mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, plan rows "
          f"{plan.rows_per_rank.tolist()} buffer {plan.buffer_rows} "
          f"(efficiency {plan.efficiency():.2f})")

    corpus = build_synthetic_corpus(
        args.data_dir, num_seqs=max(4 * plan.global_rows, 256),
        seq_len=args.seq_len + 1, vocab=cfg.vocab_size,
        rows_per_shard=64, seed=tcfg.seed)
    ds = ShardedDataset(corpus)
    sampler = HetSampler(ds, plan, seed=tcfg.seed)
    loader = PrefetchLoader(sampler, depth=args.prefetch)

    with compat.set_mesh(mesh):
        step_fn = steps_mod.build_train_step(model, tcfg, mesh)
        state = steps_mod.init_train_state(model, tcfg, mesh,
                                           jax.random.PRNGKey(tcfg.seed))
    mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        host_state, meta = mgr.restore(jax.device_get(state))
        state = jax.device_put(state.__class__(*host_state))
        start_step = meta["step"]
        print(f"[train] resumed from step {start_step}")

    monitor = StragglerMonitor(num_ranks=n_dp,
                               ema_decay=tcfg.het.straggler_ema,
                               replan_interval=tcfg.het.replan_interval)
    bspecs = named(mesh, batch_specs(cfg, mesh, plan.padded_rows))

    step = start_step
    losses = []
    t_start = time.time()
    epoch = 0
    with compat.set_mesh(mesh):
        while step < args.steps:
            for raw in loader.iter_epoch(epoch):
                if step >= args.steps:
                    break
                # hetsampler pads the *labels*: inputs are the shifted view
                batch = {
                    "inputs": jnp.asarray(raw["inputs"][:, :args.seq_len]),
                    "labels": jnp.asarray(raw["labels"][:, :args.seq_len]),
                    "weights": jnp.asarray(
                        raw["weights"][:, :args.seq_len]),
                }
                batch = jax.device_put(batch, bspecs)
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                losses.append(loss)
                step += 1
                # per-rank step times: on real fleets each host reports;
                # here every rank shares the host clock
                monitor.observe([dt] * n_dp)
                if monitor.should_replan():
                    try:
                        plan = monitor.replan(plan)
                        sampler.set_plan(plan)
                    except RemeshRequired as e:
                        print(f"[train] remesh required: {e}")
                        raise
                if step % args.log_every == 0:
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"({dt * 1e3:.0f} ms)")
                if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
                    mgr.save(step, jax.device_get(state),
                             meta={"epoch": epoch, "seed": tcfg.seed})
            epoch += 1
    mgr.save(step, jax.device_get(state),
             meta={"epoch": epoch, "seed": tcfg.seed}, block=True)
    wall = time.time() - t_start
    print(f"[train] done: {step - start_step} steps in {wall:.1f}s, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"steps": step, "wall_s": wall, "first_loss": losses[0],
            "last_loss": losses[-1]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--devices", default="1,1",
                    help="mesh shape: data,model or pod,data,model")
    ap.add_argument("--capacities", default="",
                    help="per-DP-rank relative capacities, e.g. 2,1,1,0")
    ap.add_argument("--grad-reduction", default="allreduce",
                    choices=["allreduce", "bucketed_allreduce",
                             "hierarchical"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="bucketed flat-buffer reduction: bucket payload"
                         " in MiB of f32 (0 = legacy per-leaf walk)")
    ap.add_argument("--overlap", default="none",
                    choices=["none", "buckets"],
                    help="'buckets': double-buffered per-bucket exchange"
                         " fused with per-bucket optimizer updates"
                         " (needs an explicit --grad-reduction and"
                         " --bucket-mb > 0)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "lamb"],
                    help="lamb = the paper's stated future work "
                         "(You et al. 2019) for large het batches")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--schedule", default="inverse_sqrt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/hetseq_ckpt")
    ap.add_argument("--data-dir", default="/tmp/hetseq_data")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
