"""Pure-jnp oracles for the xLSTM mLSTM (matrix-memory) scan.

The mLSTM cell (xLSTM paper, arXiv:2405.04517) per head:

    C_t = f_t C_{t-1} + i_t k_t v_t^T        (matrix memory, (dk, dv))
    n_t = f_t n_{t-1} + i_t k_t              (normalizer, (dk,))
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))

with exponential input gating stabilized in log space:
    lf_t = logsigmoid(f~_t);  m_t = max(lf_t + m_{t-1}, i~_t)
    f_t = exp(lf_t + m_{t-1} - m_t);  i_t = exp(i~_t - m_t)

``mlstm_sequential`` is the direct recurrence (ground truth).
``mlstm_chunked`` is the chunkwise-parallel form (flash-linear-attention
style): quadratic within chunks of length Q, state carry across chunks,
all in stabilized log space. Equal to sequential up to fp tolerance.

Layouts: q/k (B, S, H, dk), v (B, S, H, dv), i_pre/f_pre (B, S, H).
State: (C_hat (B,H,dk,dv), n_hat (B,H,dk), m (B,H)) where the true memory
is C = C_hat (stabilizer folded into h via m).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _init_state(b, h, dk, dv):
    return (jnp.zeros((b, h, dk, dv), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))


def mlstm_sequential(q, k, v, i_pre, f_pre, initial_state=None):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    scale = dk ** -0.5
    state = initial_state or _init_state(b, h, dk, dv)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp            # (B,H,dk), ..., (B,H)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(it - m_new)
        C = fg[..., None, None] * C + ig[..., None, None] * \
            (kt[..., :, None] * vt[..., None, :])
        n = fg[..., None] * n + ig[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C) * scale
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)) * scale,
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = (q.astype(jnp.float32).transpose(1, 0, 2, 3),
          k.astype(jnp.float32).transpose(1, 0, 2, 3),
          v.astype(jnp.float32).transpose(1, 0, 2, 3),
          i_pre.astype(jnp.float32).transpose(1, 0, 2),
          f_pre.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(q.dtype), final


def mlstm_chunked(q, k, v, i_pre, f_pre, *, chunk_size: int = 256,
                  initial_state=None):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    scale = dk ** -0.5
    orig_s = s
    cq = min(chunk_size, s)
    if s % cq != 0:
        pad = cq - s % cq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)   # pad: no input contribution
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=30.0)    # pad: f ~ 1 (keeps state)
        s += pad
    nc = s // cq

    def rs(x, feat):  # (B, S, H, F) -> (NC, B, H, CQ, F)
        return x.astype(jnp.float32).reshape(b, nc, cq, h, feat
                                             ).transpose(1, 0, 3, 2, 4)

    qc, kc, vc = rs(q, dk), rs(k, dk), rs(v, dv)
    ic = i_pre.astype(jnp.float32).reshape(b, nc, cq, h).transpose(1, 0, 3, 2)
    fc = f_pre.astype(jnp.float32).reshape(b, nc, cq, h).transpose(1, 0, 3, 2)
    state = initial_state or _init_state(b, h, dk, dv)

    idx = jnp.arange(cq)
    tri = idx[:, None] >= idx[None, :]            # causal within chunk

    def chunk_step(carry, inp):
        C, n, m = carry                           # (B,H,dk,dv),(B,H,dk),(B,H)
        qb, kb, vb, ib, fb = inp                  # (B,H,CQ,*)
        lf = jax.nn.log_sigmoid(fb)               # (B,H,CQ)
        bcs = jnp.cumsum(lf, axis=-1)             # inclusive log-decay
        g = bcs[..., -1]                          # total chunk decay
        # --- intra-chunk log weights  D_ij = b_i - b_j + i~_j  (j <= i)
        Dm = bcs[..., :, None] - bcs[..., None, :] + ib[..., None, :]
        Dm = jnp.where(tri, Dm, -jnp.inf)
        m_intra = jnp.max(Dm, axis=-1)            # (B,H,CQ)
        # --- inter-chunk: query i sees state with decay b_i, stabilizer m
        m_inter = bcs + m[..., None]
        m_i = jnp.maximum(m_intra, m_inter)
        intra = jnp.exp(Dm - m_i[..., None])      # (B,H,CQ,CQ)
        qk = jnp.einsum("bhik,bhjk->bhij", qb, kb) * scale
        w_intra = intra * qk
        num = jnp.einsum("bhij,bhjv->bhiv", w_intra, vb)
        den = jnp.sum(w_intra, axis=-1)
        inter_w = jnp.exp(m_inter - m_i)          # (B,H,CQ)
        num = num + inter_w[..., None] * \
            jnp.einsum("bhik,bhkv->bhiv", qb, C) * scale
        den = den + inter_w * jnp.einsum("bhik,bhk->bhi", qb, n) * scale
        hshift = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # --- state update (stabilized by new m')
        w_state = g[..., None] - bcs + ib         # log weight of k_j into C'
        m_new = jnp.maximum(g + m, jnp.max(w_state, axis=-1))
        carry_w = jnp.exp(g + m - m_new)
        kw = jnp.exp(w_state - m_new[..., None])
        C = carry_w[..., None, None] * C + \
            jnp.einsum("bhj,bhjk,bhjv->bhkv", kw, kb, vb)
        n = carry_w[..., None] * n + jnp.einsum("bhj,bhjk->bhk", kw, kb)
        return (C, n, m_new), hshift

    final, ys = jax.lax.scan(chunk_step, state, (qc, kc, vc, ic, fc))
    out = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)[:, :orig_s]
    return out.astype(q.dtype), final


def mlstm_decode_step(state, qt, kt, vt, it, ft):
    """Single-token recurrence. qt/kt (B,H,dk), vt (B,H,dv), it/ft (B,H)."""
    C, n, m = state
    dk = qt.shape[-1]
    scale = dk ** -0.5
    qt = qt.astype(jnp.float32)
    kt = kt.astype(jnp.float32)
    vt = vt.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(ft.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, it.astype(jnp.float32))
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(it - m_new)
    C = fg[..., None, None] * C + ig[..., None, None] * \
        (kt[..., :, None] * vt[..., None, :])
    n = fg[..., None] * n + ig[..., None] * kt
    num = jnp.einsum("bhk,bhkv->bhv", qt, C) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)) * scale,
                      jnp.exp(-m_new))
    return num / den[..., None], (C, n, m_new)
