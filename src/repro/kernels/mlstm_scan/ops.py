"""mLSTM scan op with implementation dispatch (see ref.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mlstm_scan import ref


def mlstm_scan(q, k, v, i_pre, f_pre, *, chunk_size: int = 256,
               initial_state=None, impl: str = "reference",
               interpret: bool = False):
    """Returns (h (B,S,H,dv), final_state)."""
    if impl == "sequential":
        return ref.mlstm_sequential(q, k, v, i_pre, f_pre,
                                    initial_state=initial_state)
    if impl == "reference":
        return ref.mlstm_chunked(q, k, v, i_pre, f_pre,
                                 chunk_size=chunk_size,
                                 initial_state=initial_state)
    if impl == "pallas":
        from repro.kernels.mlstm_scan.mlstm_scan import mlstm_scan_pallas
        return mlstm_scan_pallas(q, k, v, i_pre, f_pre,
                                 chunk_size=chunk_size,
                                 initial_state=initial_state,
                                 interpret=interpret)
    raise ValueError(f"unknown mlstm impl '{impl}'")


def mlstm_decode_step(state, qt, kt, vt, it, ft):
    return ref.mlstm_decode_step(state, qt, kt, vt, it, ft)
