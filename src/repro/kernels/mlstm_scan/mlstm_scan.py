"""Pallas TPU kernel for the xLSTM mLSTM chunkwise-parallel scan.

Same TPU pattern as ssd_scan: grid = (batch, head_blocks, chunks) with the
chunk axis sequential; the stabilized matrix memory (C_hat, n_hat, m) is
VMEM scratch carried across chunk ticks. Within a chunk the math is dense
MXU work on (Q, dk)/(Q, dv) tiles with log-space stabilization identical
to ref.mlstm_chunked.

Validated in interpret mode against ref.mlstm_sequential.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_BIG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref,
                  h_ref, cfin_ref, nfin_ref, mfin_ref,
                  c_ref, n_ref, m_ref, *,
                  chunk: int, num_chunks: int, dk: int, dv: int):
    ci = pl.program_id(2)
    scale = dk ** -0.5

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    qb = q_ref[0].astype(jnp.float32)            # (bh, Q, dk)
    kb = k_ref[0].astype(jnp.float32)
    vb = v_ref[0].astype(jnp.float32)            # (bh, Q, dv)
    ib = i_ref[0, :, :, 0].astype(jnp.float32)   # (bh, Q)
    fb = f_ref[0, :, :, 0].astype(jnp.float32)

    lf = jax.nn.log_sigmoid(fb)
    bcs = jnp.cumsum(lf, axis=-1)                # (bh, Q) inclusive
    g = bcs[:, -1]                               # (bh,)
    m = m_ref[...][:, 0]                         # (bh,)

    # intra-chunk log weights D_ij = b_i - b_j + i~_j (j <= i)
    Dm = bcs[:, :, None] - bcs[:, None, :] + ib[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    Dm = jnp.where(tri[None], Dm, -jnp.inf)
    m_intra = jnp.max(Dm, axis=-1)               # (bh, Q)
    m_inter = bcs + m[:, None]
    m_i = jnp.maximum(m_intra, m_inter)
    intra = jnp.exp(Dm - m_i[:, :, None])        # (bh, Q, Q)

    qk = jax.lax.dot_general(qb, kb, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32) * scale
    w_intra = intra * qk
    num = jax.lax.dot_general(w_intra, vb, (((2,), (1,)), ((0,), (0,))),
                              preferred_element_type=jnp.float32)
    den = jnp.sum(w_intra, axis=-1)              # (bh, Q)
    inter_w = jnp.exp(m_inter - m_i)             # (bh, Q)
    qC = jax.lax.dot_general(qb, c_ref[...], (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)  # (bh,Q,dv)
    num += inter_w[:, :, None] * qC * scale
    qn = jnp.einsum("hik,hk->hi", qb, n_ref[...])
    den += inter_w * qn * scale
    h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, :, None]
    h_ref[0] = h_out.astype(h_ref.dtype)

    # state update (stabilized by the new running max m')
    w_state = g[:, None] - bcs + ib              # (bh, Q)
    m_new = jnp.maximum(g + m, jnp.max(w_state, axis=-1))
    carry_w = jnp.exp(g + m - m_new)             # (bh,)
    kw = jnp.exp(w_state - m_new[:, None])       # (bh, Q)
    kkw = kw[:, :, None] * kb                    # (bh, Q, dk)
    c_ref[...] = (carry_w[:, None, None] * c_ref[...] +
                  jax.lax.dot_general(kkw, vb, (((1,), (1,)), ((0,), (0,))),
                                      preferred_element_type=jnp.float32))
    n_ref[...] = (carry_w[:, None] * n_ref[...] + jnp.sum(kkw, axis=1))
    m_ref[...] = m_new[:, None]

    @pl.when(ci == num_chunks - 1)
    def _finish():
        cfin_ref[0] = c_ref[...]
        nfin_ref[0] = n_ref[...]
        mfin_ref[0] = m_ref[...]


def mlstm_scan_pallas(
    q: jnp.ndarray,                    # (B, S, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,                    # (B, S, H, dv)
    i_pre: jnp.ndarray,                # (B, S, H)
    f_pre: jnp.ndarray,
    *,
    chunk_size: int = 128,
    initial_state=None,
    block_h: int = 4,
    interpret: bool = False,
):
    if initial_state is not None:
        raise NotImplementedError(
            "pallas mlstm_scan starts from zero state (train/prefill); "
            "decode uses mlstm_decode_step")
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    orig_s = s
    chunk = min(chunk_size, s)
    pad = (-s) % chunk
    block_h = min(block_h, h)
    if h % block_h != 0:
        block_h = 1

    def hm(t):
        return jnp.moveaxis(t, 2, 1)             # (B, H, S, F)

    qt, kt, vt = hm(q), hm(k), hm(v)
    it = hm(i_pre[..., None])
    ft = hm(f_pre[..., None])
    if pad:
        p4 = ((0, 0), (0, 0), (0, pad), (0, 0))
        qt = jnp.pad(qt, p4)
        kt = jnp.pad(kt, p4)
        vt = jnp.pad(vt, p4)
        # pad gates: i -> -inf (no input), f -> +big (keep state)
        it = jnp.pad(it, p4, constant_values=NEG_BIG)
        ft = jnp.pad(ft, p4, constant_values=30.0)
    s_p = qt.shape[2]
    nc = s_p // chunk
    nh = h // block_h

    kernel = functools.partial(_mlstm_kernel, chunk=chunk, num_chunks=nc,
                               dk=dk, dv=dv)

    hseq, cfin, nfin, mfin = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, block_h, chunk, dk),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, dk),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, dv),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, 1),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, 1),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_h, chunk, dv),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, dk, dv),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_h, dk),
                         lambda bi, hi, ci: (bi, hi, 0)),
            pl.BlockSpec((1, block_h, 1),
                         lambda bi, hi, ci: (bi, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s_p, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_h, dk, dv), jnp.float32),
            pltpu.VMEM((block_h, dk), jnp.float32),
            pltpu.VMEM((block_h, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, it, ft)
    out = jnp.moveaxis(hseq[:, :, :orig_s, :], 1, 2)
    return out, (cfin, nfin, mfin[:, :, 0])
