"""Weighted cross-entropy op with implementation dispatch (see ref.py)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.cross_entropy import ref


def weighted_cross_entropy(
    hidden: jnp.ndarray,
    lm_head: jnp.ndarray,
    labels: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    label_smoothing: float = 0.0,
    logit_softcap: float = 0.0,
    impl: str = "reference",
    chunk_size: int = 8192,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (weighted_loss_sum, weight_sum) — HetSeq aggregation contract."""
    if impl == "dense":
        return ref.ce_dense(hidden, lm_head, labels, weights,
                            label_smoothing=label_smoothing,
                            logit_softcap=logit_softcap)
    if impl == "reference":
        return ref.ce_chunked(hidden, lm_head, labels, weights,
                              label_smoothing=label_smoothing,
                              logit_softcap=logit_softcap,
                              chunk_size=chunk_size)
    if impl == "pallas":
        from repro.kernels.cross_entropy.cross_entropy import (
            cross_entropy_pallas,
        )
        return cross_entropy_pallas(hidden, lm_head, labels, weights,
                                    label_smoothing=label_smoothing,
                                    logit_softcap=logit_softcap,
                                    interpret=interpret)
    raise ValueError(f"unknown cross-entropy impl '{impl}'")
