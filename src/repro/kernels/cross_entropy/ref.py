"""Pure-jnp oracles for large-vocab weighted cross entropy.

The HetSeq weighted-loss contract: every token carries a weight (0 for
dummy/padding rows — paper M1/M3); the op returns the *weighted loss sum*
and the *weight sum* so callers aggregate exactly (never per-shard means).

``ce_dense`` materializes logits (oracle). ``ce_chunked`` scans over token
chunks so the (tokens, vocab) logit matrix never exists at full size —
and attaches a recompute *backward* (custom_vjp): under plain autodiff
the chunk scan would save each (chunk, V) logit tile as a residual,
which for a 200k vocabulary is exactly the memory the kernel exists to
avoid. The backward saves only the per-token lse and rebuilds tiles:

    dlogits = w * [(1-eps)(softmax - onehot) + eps(softmax - 1/V)]
    dh = dlogits @ W^T ;  dW += h^T @ dlogits

The Pallas kernel (cross_entropy.py) is the TPU forward with
vocab-blocked VMEM tiling.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def ce_dense(
    hidden: jnp.ndarray,      # (T, d) final hidden states
    lm_head: jnp.ndarray,     # (d, V)
    labels: jnp.ndarray,      # (T,) int32
    weights: jnp.ndarray,     # (T,) f32, 0 for dummy tokens
    *,
    label_smoothing: float = 0.0,
    logit_softcap: float = 0.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    logits = hidden.astype(jnp.float32) @ lm_head.astype(jnp.float32)
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = lse - true_logit
    if label_smoothing > 0.0:
        # fairseq-style label-smoothed CE (paper translation task, eps=0.1)
        mean_logit = jnp.mean(logits, axis=-1)
        nll = (1.0 - label_smoothing) * nll + \
            label_smoothing * (lse - mean_logit)
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w), jnp.sum(w)


def ce_chunked(
    hidden: jnp.ndarray,
    lm_head: jnp.ndarray,
    labels: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    label_smoothing: float = 0.0,
    logit_softcap: float = 0.0,
    chunk_size: int = 8192,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if logit_softcap > 0.0:
        # softcap backward needs the raw tile too — plain autodiff here
        # (only small-vocab archs use softcap; memory is not a concern)
        return _ce_chunked_fwd_only(
            hidden, lm_head, labels, weights,
            label_smoothing=label_smoothing, logit_softcap=logit_softcap,
            chunk_size=chunk_size)
    return _ce(hidden, lm_head, labels.astype(jnp.int32),
               weights.astype(jnp.float32), float(label_smoothing),
               int(chunk_size))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ce(hidden, lm_head, labels, weights, label_smoothing, chunk_size):
    return _ce_chunked_fwd_only(hidden, lm_head, labels, weights,
                                label_smoothing=label_smoothing,
                                chunk_size=chunk_size)


def _ce_fwd(hidden, lm_head, labels, weights, label_smoothing, chunk_size):
    (loss_sum, w_sum), lse = _ce_chunked_fwd_only(
        hidden, lm_head, labels, weights,
        label_smoothing=label_smoothing, chunk_size=chunk_size,
        want_lse=True)
    return (loss_sum, w_sum), (hidden, lm_head, labels, weights, lse)


def _ce_bwd(label_smoothing, chunk_size, res, cotangents):
    dloss, _ = cotangents                     # w_sum is weight-only: no grad
    hidden, lm_head, labels, weights, lse = res
    t, d = hidden.shape
    v = lm_head.shape[1]
    chunk = min(chunk_size, t)
    pad = (-t) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        weights = jnp.pad(weights, (0, pad))
        lse = jnp.pad(lse, (0, pad))
    n = hidden.shape[0] // chunk
    hc = hidden.reshape(n, chunk, d)
    lc = labels.reshape(n, chunk)
    wc = weights.reshape(n, chunk).astype(jnp.float32)
    lsec = lse.reshape(n, chunk)
    eps = label_smoothing

    def body(dw_acc, inputs):
        h, lab, w, ls = inputs
        logits = jax.lax.dot_general(                    # recompute tile
            h, lm_head, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        p = jnp.exp(logits - ls[:, None])                # softmax via lse
        onehot = jax.nn.one_hot(lab, v, dtype=jnp.float32)
        dlogits = (1.0 - eps) * (p - onehot)
        if eps > 0.0:
            dlogits = dlogits + eps * (p - 1.0 / v)
        dlogits = (dlogits * (w * dloss)[:, None]).astype(h.dtype)
        dh = jax.lax.dot_general(
            dlogits, lm_head, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        dw_acc = dw_acc + jax.lax.dot_general(
            h, dlogits, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dw_acc, dh

    dw0 = jnp.zeros((d, v), jnp.float32)
    dw, dhs = jax.lax.scan(body, dw0, (hc, lc, wc, lsec))
    dh = dhs.reshape(-1, d)[:t]
    return (dh.astype(hidden.dtype), dw.astype(lm_head.dtype), None, None)


_ce.defvjp(_ce_fwd, _ce_bwd)


def _ce_chunked_fwd_only(
    hidden: jnp.ndarray,
    lm_head: jnp.ndarray,
    labels: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    label_smoothing: float = 0.0,
    logit_softcap: float = 0.0,
    chunk_size: int = 8192,
    want_lse: bool = False,
):
    t, d = hidden.shape
    orig_t = t
    chunk = min(chunk_size, t)
    if t % chunk != 0:
        pad = chunk - t % chunk
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        weights = jnp.pad(weights, (0, pad))
        t = t + pad
    n = t // chunk
    hc = hidden.reshape(n, chunk, d)
    lc = labels.reshape(n, chunk)
    wc = weights.reshape(n, chunk)

    def body(carry, inputs):
        loss_sum, w_sum = carry
        h, lab, w = inputs
        # native-dtype operands + f32 accumulation: avoids materializing
        # fp32 copies of hidden/lm_head (XLA hoists per-chunk converts
        # into whole-array converts outside the scan)
        logits = jax.lax.dot_general(
            h, lm_head, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if logit_softcap > 0.0:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        true_logit = jnp.take_along_axis(logits, lab[:, None], axis=-1)[:, 0]
        nll = lse - true_logit
        if label_smoothing > 0.0:
            mean_logit = jnp.mean(logits, axis=-1)
            nll = (1.0 - label_smoothing) * nll + \
                label_smoothing * (lse - mean_logit)
        w = w.astype(jnp.float32)
        return (loss_sum + jnp.sum(nll * w), w_sum + jnp.sum(w)), lse

    (loss_sum, w_sum), lses = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, wc))
    if want_lse:
        return (loss_sum, w_sum), lses.reshape(-1)[:orig_t]
    return loss_sum, w_sum
