"""Pallas TPU blocked large-vocab cross-entropy kernel.

Motivation: phi4-mini has a 200,064-entry vocabulary; materializing the
(tokens, vocab) logit matrix at bf16 for train_4k (1M tokens global) is
the dominant activation. This kernel fuses the lm_head matmul with an
online logsumexp so only (block_t, block_v) logit tiles ever exist, in
VMEM.

Design:
  * grid = (token_blocks, vocab_blocks); vocab is the innermost
    *sequential* axis; per-token running (max, sumexp, true_logit,
    sum_logits) accumulators live in VMEM scratch across vocab ticks.
  * hidden tile (block_t, D) stays resident across the whole vocab sweep
    of one token block (constant index_map on the vocab axis); lm_head
    streams as (D, block_v) MXU-aligned tiles.
  * labels arrive as one-hot-free int32; the true logit is extracted with
    a where-sum inside the tile that contains it.
  * emits per-token nll and weight untouched — the weighted HetSeq
    (sum, weight-sum) contract is applied by ops.py so the aggregation
    math is shared with the reference path.

Validated in interpret mode against ref.ce_dense.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _ce_kernel(h_ref, w_ref, lab_ref, nll_ref,
               m_ref, l_ref, true_ref, sum_ref, *,
               block_t: int, block_v: int, vocab: int, num_v_blocks: int,
               label_smoothing: float, logit_softcap: float):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        true_ref[...] = jnp.zeros_like(true_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    h = h_ref[...].astype(jnp.float32)                     # (bt, D)
    w = w_ref[...].astype(jnp.float32)                     # (D, bv)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    col = (vb * block_v +
           jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1))
    valid = col < vocab
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = (l_ref[...] * corr[:, None] +
                  jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1,
                          keepdims=True))
    m_ref[...] = m_new[:, None]

    labels = lab_ref[...][:, 0]                            # (bt,) int32
    is_label = col == labels[:, None]
    true_ref[...] += jnp.sum(jnp.where(is_label, logits, 0.0), axis=-1,
                             keepdims=True)
    if label_smoothing > 0.0:
        sum_ref[...] += jnp.sum(jnp.where(valid, logits, 0.0), axis=-1,
                                keepdims=True)

    @pl.when(vb == num_v_blocks - 1)
    def _finish():
        lse = m_ref[...][:, 0] + jnp.log(jnp.maximum(l_ref[...][:, 0], 1e-30))
        nll = lse - true_ref[...][:, 0]
        if label_smoothing > 0.0:
            mean_logit = sum_ref[...][:, 0] / vocab
            nll = (1.0 - label_smoothing) * nll + \
                label_smoothing * (lse - mean_logit)
        nll_ref[...] = nll[:, None]


def cross_entropy_pallas(
    hidden: jnp.ndarray,                 # (T, D)
    lm_head: jnp.ndarray,                # (D, V)
    labels: jnp.ndarray,                 # (T,) int32
    weights: jnp.ndarray,                # (T,) f32
    *,
    label_smoothing: float = 0.0,
    logit_softcap: float = 0.0,
    block_t: int = 256,
    block_v: int = 1024,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    t, d = hidden.shape
    v = lm_head.shape[1]
    block_t = min(block_t, max(t, 8))
    block_v = min(block_v, max(v, 128))
    pad_t = (-t) % block_t
    pad_v = (-v) % block_v
    if pad_t:
        hidden = jnp.pad(hidden, ((0, pad_t), (0, 0)))
        labels = jnp.pad(labels, (0, pad_t))
    if pad_v:
        lm_head = jnp.pad(lm_head, ((0, 0), (0, pad_v)))
    n_t = hidden.shape[0] // block_t
    n_v = lm_head.shape[1] // block_v

    kernel = functools.partial(
        _ce_kernel, block_t=block_t, block_v=block_v, vocab=v,
        num_v_blocks=n_v, label_smoothing=label_smoothing,
        logit_softcap=logit_softcap)

    nll = pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((d, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((hidden.shape[0], 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),    # running max
            pltpu.VMEM((block_t, 1), jnp.float32),    # running sumexp
            pltpu.VMEM((block_t, 1), jnp.float32),    # true logit
            pltpu.VMEM((block_t, 1), jnp.float32),    # sum logits (smoothing)
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(hidden, lm_head.astype(hidden.dtype), labels[:, None].astype(jnp.int32))
    nll = nll[:t, 0]
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w), jnp.sum(w)
