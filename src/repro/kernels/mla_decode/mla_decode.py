"""Pallas TPU kernel: absorbed-MLA flash decode (one HBM pass).

§Perf pair 3's conclusion realized at kernel level: the XLA dense decode
reads the latent cache TWICE (score matmul + value matmul) and round-
trips a (B, H, S) probability matrix through HBM; a host-level chunk
loop can't fix it because the cache's S dim is sharded (it breaks the
auto split-K — measured +60% ICI). Inside a kernel the fix is natural:

  grid = (batch, S_chunks) with the chunk axis sequential; each (chunk,
  r) latent tile is loaded into VMEM ONCE and used for BOTH the score
  contraction and the weighted value accumulation; the fp32 online-
  softmax state (acc (H, r), m, l) lives in scratch across chunks.

HBM traffic per token-step: |cache| instead of 2|cache| + |probs|
(~2.2x less at 32k context). On a sequence-sharded cache the kernel runs
per shard under shard_map with an (m, l, acc) cross-shard combine — the
same split-K math the dense path gets from XLA, minus the double read.

Validated in interpret mode against ref.mla_decode_dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(qa_ref, qr_ref, ckv_ref, kr_ref, len_ref, out_ref,
            acc_ref, m_ref, l_ref, *, scale, chunk, num_chunks,
            heads, rank):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qa = qa_ref[0]                                 # (H, r)
    qr = qr_ref[0]                                 # (H, Dr)
    ckv = ckv_ref[0]                               # (chunk, r) — ONE load
    kr = kr_ref[0]                                 # (chunk, Dr)
    kv_len = len_ref[0, 0]

    s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) +
         jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)) * scale
    kpos = ci * chunk + jax.lax.broadcasted_iota(
        jnp.int32, (heads, chunk), 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr[:, None] + \
        jnp.sum(p, axis=-1, keepdims=True)
    # value accumulation REUSES the VMEM-resident ckv tile
    acc_ref[...] = (acc_ref[...] * corr[:, None] +
                    jax.lax.dot_general(
                        p.astype(ckv.dtype), ckv,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]

    @pl.when(ci == num_chunks - 1)
    def _finish():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def mla_decode_pallas(q_abs, q_r, ckv, kr, kv_len, scale,
                      *, chunk: int = 512, interpret: bool = False):
    b, h, r = q_abs.shape
    dr = q_r.shape[-1]
    s = ckv.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))
    n_chunks = ckv.shape[1] // chunk

    kernel = functools.partial(_kernel, scale=float(scale), chunk=chunk,
                               num_chunks=n_chunks, heads=h, rank=r)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, h, r), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, h, dr), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, chunk, r), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dr), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1), lambda bi, ci: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, r), lambda bi, ci: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h, r), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q_abs, q_r, ckv, kr, kv_len.reshape(b, 1).astype(jnp.int32))
    return out


# --------------------------------------------------------------------------
# paged variant: block-table gather inside the kernel (serving hot path)
# --------------------------------------------------------------------------


def _paged_kernel(tables, lens, qa_ref, qr_ref, ckv_ref, kr_ref, out_ref,
                  acc_ref, m_ref, l_ref, *, scale, block_size, max_blocks,
                  null_block, heads):
    """Grid (B, MB); j sequential. The chunk axis of the contiguous
    kernel becomes the sequence's logical block axis: each step's
    (bs, r) latent tile is DMA'd straight from the pool block named by
    the block table (scalar-prefetch index_map) — NULL blocks arrive
    clamped and are zeroed, then fully masked by kv_len. The fp32
    online-softmax state persists in scratch; the VMEM-resident ckv
    tile is reused for both the score and the value matmul, preserving
    the one-HBM-pass property on the paged pool.
    """
    bi = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    is_null = tables[bi, j] == null_block
    qa = qa_ref[0]                                 # (H, r)
    qr = qr_ref[0]                                 # (H, Dr)
    ckv = jnp.where(is_null, 0, ckv_ref[0])        # (bs, r) — ONE load
    kr = jnp.where(is_null, 0, kr_ref[0])          # (bs, Dr)

    s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) +
         jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)) * scale
    kpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (heads, block_size), 1)
    s = jnp.where(kpos < lens[bi], s, NEG_INF)

    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr[:, None] + \
        jnp.sum(p, axis=-1, keepdims=True)
    # value accumulation REUSES the VMEM-resident ckv tile
    acc_ref[...] = (acc_ref[...] * corr[:, None] +
                    jax.lax.dot_general(
                        p.astype(ckv.dtype), ckv,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]

    @pl.when(j == max_blocks - 1)
    def _finish():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def mla_decode_paged_pallas(q_abs, q_r, ckv_pool, kr_pool, block_tables,
                            kv_lens, scale, *, interpret: bool = False):
    """Absorbed-MLA decode over a paged latent pool, gather in-kernel.

    q_abs (B, H, r); q_r (B, H, Dr); ckv_pool (N, bs, r); kr_pool
    (N, bs, Dr); block_tables (B, MB) int32 with NULL == N; kv_lens (B,)
    int32 EFFECTIVE lengths (positions >= kv_lens[i] masked). Returns
    (B, H, r) fp32 attention output in latent space, within compute-
    dtype tolerance of the materialize-then-attend reference.
    """
    b, h, r = q_abs.shape
    dr = q_r.shape[-1]
    n_pool, bs, _ = ckv_pool.shape
    mb = block_tables.shape[1]

    kernel = functools.partial(
        _paged_kernel, scale=float(scale), block_size=bs, max_blocks=mb,
        null_block=n_pool, heads=h)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mb),
        in_specs=[
            pl.BlockSpec((1, h, r), lambda bi, j, tbl, lens: (bi, 0, 0)),
            pl.BlockSpec((1, h, dr), lambda bi, j, tbl, lens: (bi, 0, 0)),
            pl.BlockSpec((1, bs, r),
                         lambda bi, j, tbl, lens: (
                             jnp.minimum(tbl[bi, j], n_pool - 1), 0, 0)),
            pl.BlockSpec((1, bs, dr),
                         lambda bi, j, tbl, lens: (
                             jnp.minimum(tbl[bi, j], n_pool - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, r),
                               lambda bi, j, tbl, lens: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, r), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_lens.astype(jnp.int32),
      q_abs, q_r, ckv_pool, kr_pool)
