"""Pure-jnp oracle for absorbed-MLA single-token decode attention.

Inputs (per layer, per device shard):
  q_abs (B, H, r)   queries absorbed through W_uk into latent space
  q_r   (B, H, Dr)  decoupled RoPE queries
  ckv   (B, S, r)   compressed latent cache
  kr    (B, S, Dr)  shared RoPE key cache
  kv_len (B,)       valid cache length per sequence
Output: out_lat (B, H, r) — the attention-weighted latent (the caller
applies W_uv / wo).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mla_decode_dense(q_abs, q_r, ckv, kr, kv_len, scale):
    scores = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(jnp.float32),
                         ckv.astype(jnp.float32)) +
              jnp.einsum("bhd,bsd->bhs", q_r.astype(jnp.float32),
                         kr.astype(jnp.float32))) * scale
    s = ckv.shape[1]
    mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32))
