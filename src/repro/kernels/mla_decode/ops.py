"""Absorbed-MLA decode op with implementation dispatch (see ref.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mla_decode import ref


def mla_decode_attention(q_abs, q_r, ckv, kr, kv_len, scale,
                         *, impl: str = "dense", chunk: int = 512,
                         interpret: bool = False):
    if impl == "dense":
        return ref.mla_decode_dense(q_abs, q_r, ckv, kr, kv_len, scale)
    if impl == "pallas":
        from repro.kernels.mla_decode.mla_decode import mla_decode_pallas
        return mla_decode_pallas(q_abs, q_r, ckv, kr, kv_len, scale,
                                 chunk=chunk, interpret=interpret)
    raise ValueError(f"unknown mla decode impl '{impl}'")


def mla_decode_paged_attention(q_abs, q_r, ckv_pool, kr_pool,
                               block_tables, kv_lens, scale,
                               *, impl: str = "reference",
                               interpret: bool = False):
    """Absorbed-MLA decode over a paged latent pool.

    ckv_pool (N, bs, r); kr_pool (N, bs, Dr); block_tables (B, MB)
    int32 with NULL == N; kv_lens (B,) effective lengths. Returns
    out_lat (B, H, r) fp32.

    ``impl``: "reference"/"dense" gathers the mapped blocks into a
    dense (B, MB*bs, ...) window (NULL fills zeros) and runs
    ``ref.mla_decode_dense``; "pallas" streams pool blocks through the
    block table inside the kernel (one HBM pass, no window).
    """
    if impl in ("reference", "dense"):
        b = q_abs.shape[0]
        ckv_g = ckv_pool.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, ckv_pool.shape[-1])
        kr_g = kr_pool.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, kr_pool.shape[-1])
        return ref.mla_decode_dense(q_abs, q_r, ckv_g, kr_g, kv_lens,
                                    scale)
    if impl == "pallas":
        from repro.kernels.mla_decode.mla_decode import (
            mla_decode_paged_pallas,
        )
        return mla_decode_paged_pallas(q_abs, q_r, ckv_pool, kr_pool,
                                       block_tables, kv_lens, scale,
                                       interpret=interpret)
    raise ValueError(f"unknown mla decode impl '{impl}'")
