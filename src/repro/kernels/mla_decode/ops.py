"""Absorbed-MLA decode op with implementation dispatch (see ref.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mla_decode import ref


def mla_decode_attention(q_abs, q_r, ckv, kr, kv_len, scale,
                         *, impl: str = "dense", chunk: int = 512,
                         interpret: bool = False):
    if impl == "dense":
        return ref.mla_decode_dense(q_abs, q_r, ckv, kr, kv_len, scale)
    if impl == "pallas":
        from repro.kernels.mla_decode.mla_decode import mla_decode_pallas
        return mla_decode_pallas(q_abs, q_r, ckv, kr, kv_len, scale,
                                 chunk=chunk, interpret=interpret)
    raise ValueError(f"unknown mla decode impl '{impl}'")
