"""Int8 quant/dequant op with implementation dispatch (see ref.py)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize import ref


def quantize_int8(
    x: jnp.ndarray, *, block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference", interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "reference":
        return ref.quantize_int8(x, block_size=block_size, key=key)
    if impl == "pallas":
        from repro.kernels.quantize.quantize import quantize_int8_pallas
        return quantize_int8_pallas(x, block_size=block_size, key=key,
                                    interpret=interpret)
    raise ValueError(f"unknown quantize impl '{impl}'")


def dequantize_int8(q, scale, shape, block_size: int = 256):
    return ref.dequantize_int8(q, scale, shape, block_size)


def dequant_accum(q: jnp.ndarray, scale: jnp.ndarray, *,
                  impl: str = "reference",
                  interpret: bool = False) -> jnp.ndarray:
    """Fused receive-side dequantize + accumulate over the rank axis.

    ``q``: (ranks, blocks, block_size) int8, ``scale``: (ranks, blocks)
    f32 -> (blocks, block_size) f32 shard sum.
    """
    if impl == "reference":
        return ref.dequant_accum(q, scale)
    if impl == "pallas":
        from repro.kernels.quantize.quantize import dequant_accum_pallas
        return dequant_accum_pallas(q, scale, interpret=interpret)
    raise ValueError(f"unknown dequant_accum impl '{impl}'")
