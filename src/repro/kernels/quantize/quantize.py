"""Pallas TPU kernel for per-block int8 quantization (grad compression).

Used on the cross-pod (DCN) gradient reduction path: fp32 gradient shards
are quantized to int8 + per-block fp32 scales (4.06x compression) before
the pod-axis all-reduce. Stochastic rounding keeps the compressed update
unbiased; the noise tensor is generated outside the kernel with
jax.random so the kernel stays deterministic and testable.

Grid tiles rows of a (num_blocks, block_size) view; absmax, scale and
rounding are all VPU element-wise work — the kernel exists to keep the
quantize fused and VMEM-resident next to the collective rather than
round-tripping through HBM.

Validated in interpret mode against ref.quantize_int8.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, noise_ref, q_ref, s_ref, *, stochastic: bool):
    x = x_ref[...].astype(jnp.float32)                # (rows, block)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = x / scale
    if stochastic:
        scaled = scaled + (noise_ref[...] - 0.5)
    q = jnp.clip(jnp.round(scaled), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8_pallas(
    x: jnp.ndarray,
    *,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    rows_per_tile: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = -(-n // block_size) * block_size
    flat = jnp.pad(flat, (0, padded - n))
    blocks = flat.reshape(-1, block_size)
    nb = blocks.shape[0]
    rows = min(rows_per_tile, nb)
    pad_rows = (-nb) % rows
    if pad_rows:
        blocks = jnp.pad(blocks, ((0, pad_rows), (0, 0)))
    nb_p = blocks.shape[0]
    stochastic = key is not None
    noise = (jax.random.uniform(key, blocks.shape) if stochastic
             else jnp.zeros_like(blocks))

    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, stochastic=stochastic),
        grid=(nb_p // rows,),
        in_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_p, block_size), jnp.int8),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(blocks, noise)
    return q[:nb], s[:nb, 0]
