"""Pallas TPU kernels for per-block int8 quantization (grad compression).

Used on the cross-pod (DCN) gradient reduction path: fp32 gradient shards
are quantized to int8 + per-block fp32 scales (4.06x compression) before
the pod-axis exchange. Stochastic rounding keeps the compressed update
unbiased; the noise tensor is generated outside the kernel with
jax.random so the kernel stays deterministic and testable.

Two kernels:
  * ``quantize_int8_pallas`` — send side. Grid tiles rows of a
    (num_blocks, block_size) view; absmax, scale and rounding are all
    VPU element-wise work — the kernel exists to keep the quantize
    fused and VMEM-resident next to the collective rather than
    round-tripping through HBM. The bucketed reduction
    (core/buckets.py) calls it ONCE over the whole concatenated bucket
    stack, not per pytree leaf.
  * ``dequant_accum_pallas`` — receive side. After the cross-pod
    exchange each rank holds one int8 contribution per peer for its
    shard; this kernel fuses dequantize (q * scale) with the
    accumulation over peers, so the per-peer f32 expansion never leaves
    VMEM. The peer loop is unrolled (pod counts are small static
    numbers).

Both validated in interpret mode against ref.py oracles.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _quant_kernel(x_ref, noise_ref, q_ref, s_ref, *, stochastic: bool):
    x = x_ref[...].astype(jnp.float32)                # (rows, block)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = x / scale
    if stochastic:
        scaled = scaled + (noise_ref[...] - 0.5)
    q = jnp.clip(jnp.round(scaled), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8_pallas(
    x: jnp.ndarray,
    *,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    rows_per_tile: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = -(-n // block_size) * block_size
    flat = jnp.pad(flat, (0, padded - n))
    blocks = flat.reshape(-1, block_size)
    nb = blocks.shape[0]
    rows = min(rows_per_tile, nb)
    pad_rows = (-nb) % rows
    if pad_rows:
        blocks = jnp.pad(blocks, ((0, pad_rows), (0, 0)))
    nb_p = blocks.shape[0]
    stochastic = key is not None
    noise = (jax.random.uniform(key, blocks.shape) if stochastic
             else jnp.zeros_like(blocks))

    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, stochastic=stochastic),
        grid=(nb_p // rows,),
        in_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_p, block_size), jnp.int8),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(blocks, noise)
    return q[:nb], s[:nb, 0]


# --------------------------------------------------------------------------
# fused dequantize-accumulate (receive side of the bucketed reduction)
# --------------------------------------------------------------------------


def _dequant_accum_kernel(q_ref, s_ref, o_ref, *, ranks: int):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for r in range(ranks):                       # static unroll, ranks small
        acc = acc + q_ref[r].astype(jnp.float32) * s_ref[r]
    o_ref[...] = acc


def dequant_accum_pallas(
    q: jnp.ndarray,
    s: jnp.ndarray,
    *,
    rows_per_tile: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """sum_r q[r] * s[r] for q (ranks, blocks, B) int8, s (ranks, blocks).

    Returns (blocks, B) f32. One grid step per row tile; the rank loop
    is unrolled inside the kernel so the dequantized f32 values are
    consumed by the accumulator without an HBM round trip.
    """
    ranks, nb, block = q.shape
    rows = min(rows_per_tile, nb)
    pad_rows = (-nb) % rows
    if pad_rows:
        q = jnp.pad(q, ((0, 0), (0, pad_rows), (0, 0)))
        s = jnp.pad(s, ((0, 0), (0, pad_rows)))
    nb_p = q.shape[1]
    out = pl.pallas_call(
        functools.partial(_dequant_accum_kernel, ranks=ranks),
        grid=(nb_p // rows,),
        in_specs=[
            pl.BlockSpec((ranks, rows, block), lambda i: (0, i, 0)),
            pl.BlockSpec((ranks, rows, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb_p, block), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, s[..., None].astype(jnp.float32))
    return out[:nb]
