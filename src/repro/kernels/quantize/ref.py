"""Pure-jnp oracle for per-block int8 quantization (gradient compression).

Used by core/compression.py on the cross-pod (DCN) gradient reduction —
the beyond-paper distributed-optimization trick. Per-block absmax scaling;
optional stochastic rounding keeps the compressed SGD unbiased.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(
    x: jnp.ndarray,
    *,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(flat) f32 -> (int8 values, f32 per-block scales)."""
    from repro import compat

    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = -(-n // block_size) * block_size
    flat = compat.pad_trailing(flat, padded - n)
    blocks = flat.reshape(-1, block_size)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    if key is not None:
        noise = jax.random.uniform(key, scaled.shape) - 0.5
        q = jnp.clip(jnp.round(scaled + noise), -127, 127)
    else:
        q = jnp.clip(jnp.round(scaled), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize_int8(
    q: jnp.ndarray, scale: jnp.ndarray, shape, block_size: int = 256,
) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def dequant_accum(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Fused dequantize-and-accumulate oracle (reduction receive side).

    ``q``: (ranks, blocks, block_size) int8 — one quantized contribution
    per peer rank; ``scale``: (ranks, blocks) f32 per-block scales.
    Returns (blocks, block_size) f32 = sum_r q[r] * scale[r] — the
    summed shard without ever materializing per-rank f32 copies.
    """
    return jnp.einsum("rbk,rb->bk", q.astype(jnp.float32),
                      scale.astype(jnp.float32))
