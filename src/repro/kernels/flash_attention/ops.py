"""Public attention op with implementation dispatch.

``impl``:
  * "reference"  — chunked online-softmax jnp (CPU dry-run / oracle-adjacent)
  * "dense"      — full score matrix (tiny shapes, tests)
  * "pallas"     — Pallas TPU kernel (``flash_attention.py``); on non-TPU
                   backends tests run it with interpret=True.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    impl: str = "reference",
    chunk_size: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "dense":
        return ref.mha_dense(q, k, v, causal=causal, q_offset=q_offset,
                             softmax_scale=softmax_scale, kv_len=kv_len)
    if impl == "reference":
        return ref.mha_chunked(q, k, v, causal=causal, q_offset=q_offset,
                               softmax_scale=softmax_scale,
                               chunk_size=chunk_size, kv_len=kv_len)
    if impl == "pallas":
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas,
        )
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset,
            softmax_scale=softmax_scale, interpret=interpret)
    raise ValueError(f"unknown attention impl '{impl}'")
