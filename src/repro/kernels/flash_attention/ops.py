"""Public attention op with implementation dispatch.

``impl``:
  * "reference"  — chunked online-softmax jnp (CPU dry-run / oracle-adjacent)
  * "dense"      — full score matrix (tiny shapes, tests)
  * "pallas"     — Pallas TPU kernel (``flash_attention.py``); on non-TPU
                   backends tests run it with interpret=True.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    impl: str = "reference",
    chunk_size: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "dense":
        return ref.mha_dense(q, k, v, causal=causal, q_offset=q_offset,
                             softmax_scale=softmax_scale, kv_len=kv_len)
    if impl == "reference":
        return ref.mha_chunked(q, k, v, causal=causal, q_offset=q_offset,
                               softmax_scale=softmax_scale,
                               chunk_size=chunk_size, kv_len=kv_len)
    if impl == "pallas":
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas,
        )
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset,
            softmax_scale=softmax_scale, interpret=interpret)
    raise ValueError(f"unknown attention impl '{impl}'")


def flash_decode_paged(
    q: jnp.ndarray,                      # (B, 1, H, D)
    k_pool: jnp.ndarray,                 # (N, bs, Hkv, D)
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,           # (B, MB) int32, NULL == N
    kv_lens: jnp.ndarray,                # (B,) int32 effective lengths
    *,
    softmax_scale: Optional[float] = None,
    impl: str = "reference",
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-query GQA decode over a paged pool (serving hot path).

    ``impl``:
      * "reference"/"dense" — materialize-then-attend: gather each
        sequence's mapped blocks into a dense (B, MB*bs, Hkv, D) window
        in HBM (NULL blocks fill with zeros) and run ``ref.mha_dense``.
      * "pallas" — in-kernel block gather: the block-table lookup drives
        the kernel's DMA index_map, so no window is ever materialized.
        fp32-bitwise vs the reference path.

    ``kv_lens`` are effective context lengths: positions >= kv_lens[i]
    are masked, so callers attending to a just-written token pass
    ``cached + 1``.
    """
    if impl in ("reference", "dense"):
        b = q.shape[0]
        k_g = k_pool.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, *k_pool.shape[2:])
        v_g = v_pool.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, *v_pool.shape[2:])
        return ref.mha_dense(q, k_g, v_g, causal=False,
                             softmax_scale=softmax_scale, kv_len=kv_lens)
    if impl == "pallas":
        from repro.kernels.flash_attention.flash_attention import (
            flash_decode_paged_pallas,
        )
        return flash_decode_paged_pallas(
            q, k_pool, v_pool, block_tables, kv_lens,
            softmax_scale=softmax_scale, interpret=interpret)
    raise ValueError(f"unknown attention impl '{impl}'")
