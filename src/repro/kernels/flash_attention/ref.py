"""Pure-jnp oracles for flash attention.

Two references:
  * ``mha_dense`` — materializes the full score matrix; the ground-truth
    oracle for kernel tests (small shapes only).
  * ``mha_chunked`` — online-softmax scan over KV chunks; numerically equal
    to ``mha_dense`` but with O(S * chunk) memory. This is what the model
    lowers on backends where the Pallas kernel is unavailable (CPU dry-run),
    so dry-run FLOPs/memory are honest.

Layouts: q (B, Sq, H, D); k/v (B, Skv, Hkv, D) with H % Hkv == 0 (GQA).
``q_offset`` is the absolute position of q[0] (decode: q_offset = pos).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(x: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv * q_per_kv, D) by head repetition."""
    if q_per_kv == 1:
        return x
    b, s, hkv, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, hkv, q_per_kv, d))
    return x.reshape(b, s, hkv * q_per_kv, d)


def mha_dense(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    kv_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Dense-softmax oracle. kv_len (B,) masks positions >= kv_len."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        mask = mask[None, :, :] & (kpos[None, None, :] < kv_len[:, None, None])
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    else:
        scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    chunk_size: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Flash-style attention with a flash-style *backward*.

    The plain chunked scan (``_mha_chunked_fwd_only``) is numerically the
    oracle, but under ``jax.grad`` its scan saves per-chunk probability
    tiles as residuals — O(S^2) memory, exactly what flash attention
    exists to avoid. This wrapper attaches the standard recompute
    backward (custom_vjp): saves only (q, k, v, out, lse) and rebuilds
    each (Sq, chunk) tile in both passes. This is also what makes the
    dry-run roofline honest: HLO memory stays O(S * chunk).
    """
    scale = (softmax_scale if softmax_scale is not None
             else q.shape[-1] ** -0.5)
    if kv_len is None:
        kv_len = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
    return _flash(q, k, v, kv_len, bool(causal), int(q_offset),
                  float(scale), int(chunk_size))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, kv_len, causal, q_offset, scale, chunk_size):
    out, _ = _flash_fwd_impl(q, k, v, kv_len, causal, q_offset, scale,
                             chunk_size)
    return out


def _flash_fwd_impl(q, k, v, kv_len, causal, q_offset, scale, chunk_size):
    return _mha_chunked_fwd_only(
        q, k, v, causal=causal, q_offset=q_offset, softmax_scale=scale,
        chunk_size=chunk_size, kv_len=kv_len, want_lse=True)


def _flash_fwd(q, k, v, kv_len, causal, q_offset, scale, chunk_size):
    out, lse = _flash_fwd_impl(q, k, v, kv_len, causal, q_offset, scale,
                               chunk_size)
    return out, (q, k, v, kv_len, out, lse)


def _flash_bwd(causal, q_offset, scale, chunk_size, res, dout):
    q, k, v, kv_len, out, lse = res
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    q_per_kv = h // hkv
    chunk = min(chunk_size, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk

    Dv = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                 axis=-1)                              # (B, Sq, H)
    qpos = jnp.arange(sq) + q_offset

    kc = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        dq_acc, cidx = carry
        kb, vb = inputs
        kbf = _repeat_kv(kb, q_per_kv)
        vbf = _repeat_kv(vb, q_per_kv)
        s = jnp.einsum("bqhd,bkhd->bqhk", q, kbf,
                       preferred_element_type=jnp.float32) * scale
        kpos = cidx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
        bmask = mask[None, :, :] & (kpos[None, None, :] <
                                    kv_len[:, None, None])
        p = jnp.where(bmask[:, :, None, :],
                      jnp.exp(s - lse[..., None]), 0.0)
        pl = p.astype(q.dtype)
        dv_b = jnp.einsum("bqhk,bqhd->bkhd", pl, dout,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhd,bkhd->bqhk", dout, vbf,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - Dv[..., None]) * scale).astype(q.dtype)
        dq_acc = dq_acc + jnp.einsum("bqhk,bkhd->bqhd", ds, kbf,
                                     preferred_element_type=jnp.float32)
        dk_b = jnp.einsum("bqhk,bqhd->bkhd", ds, q,
                          preferred_element_type=jnp.float32)
        return (dq_acc, cidx + 1), (dk_b, dv_b)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    (dq, _), (dks, dvs) = jax.lax.scan(body, (dq0, jnp.int32(0)),
                                       (kc, vc))
    skv_p = n_chunks * chunk
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, skv_p, h, d)[:, :skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, skv_p, h, d)[:, :skv]
    if q_per_kv > 1:                       # GQA: fold repeated heads back
        dk = dk.reshape(b, skv, hkv, q_per_kv, d).sum(axis=3)
        dv = dv.reshape(b, skv, hkv, q_per_kv, d).sum(axis=3)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _mha_chunked_fwd_only(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    chunk_size: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
    want_lse: bool = False,
):
    """Online-softmax (flash-style) scan over KV chunks. fp32 accumulators."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    q_per_kv = h // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    chunk = min(chunk_size, skv)
    if skv % chunk != 0:
        # pad KV to a chunk multiple; padded keys are masked out via kv_len
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((b,), skv, dtype=jnp.int32)
    n_chunks = k.shape[1] // chunk

    qpos = jnp.arange(sq) + q_offset

    kc = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        acc, m, l, cidx = carry                # cidx loop-carried: keeps
        kb, vb = inputs                        # masks per-chunk (no hoist)
        kb = _repeat_kv(kb, q_per_kv)
        vb = _repeat_kv(vb, q_per_kv)
        # native-dtype qk with f32 accumulation: no fp32 copy of q/k
        s = jnp.einsum("bqhd,bkhd->bqhk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = cidx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            bmask = mask[None, :, :] & (kpos[None, None, :] < kv_len[:, None, None])
            s = jnp.where(bmask[:, :, None, :], s, NEG_INF)
        else:
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new, cidx + 1), None

    acc0 = jnp.zeros((b, sq, h, d), dtype=jnp.float32)
    m0 = jnp.full((b, sq, h), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, sq, h), dtype=jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(
        body, (acc0, m0, l0, jnp.int32(0)), (kc, vc))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    if want_lse:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))       # (B, Sq, H)
        return out, lse
    return out
