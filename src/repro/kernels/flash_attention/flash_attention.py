"""Pallas TPU flash-attention forward kernel.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
    innermost, *sequential* ("arbitrary") grid axis, so the fp32 running
    softmax state (acc, m, l) lives in VMEM scratch and persists across kv
    iterations — the TPU grid is executed in order, which replaces the
    CUDA notion of a per-CTA loop over KV tiles.
  * BlockSpec tiles: q (1, 1, block_q, D) and k/v (1, 1, block_kv, D) are
    MXU-aligned (block sizes multiples of 128 where the head dim allows);
    GQA is expressed in the k/v index_map (kv head = q head // q_per_kv)
    so no repeated-KV tensor is ever materialized in HBM.
  * Causal masking is positional (q_offset supports decode/chunked
    prefill); fully-masked kv blocks are skipped via ``pl.when`` so they
    cost a grid tick but no FLOPs.

Validated in interpret mode against ref.mha_dense (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, block_q: int, block_kv: int, causal: bool,
               q_offset: int, seq_kv: int, num_kv_blocks: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block is live unless causal pruning removes it entirely:
    # smallest q position in this block >= largest kv position needed.
    q_start = qb * block_q + q_offset
    kv_start = kb * block_kv
    live = (not causal) or True
    run = jnp.logical_or(jnp.logical_not(jnp.bool_(causal)),
                         q_start + block_q - 1 >= kv_start)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = (q_start +
                jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
        kpos = (kv_start +
                jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1))
        mask = kpos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]                          # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...] * corr[:, None] +
                      jnp.sum(p, axis=-1, keepdims=True))
        acc_ref[...] = (acc_ref[...] * corr[:, None] +
                        jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]

    @pl.when(kb == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,                      # (B, Sq, H, D)
    k: jnp.ndarray,                      # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    q_per_kv = h // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5

    block_q = min(block_q, max(sq, 8))
    block_kv = min(block_kv, max(skv, 8))
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    # (B, S, H, D) -> (B, H, S, D) so the tile is a clean (block, D) matrix
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_q = qt.shape[2] // block_q
    n_kv = kt.shape[2] // block_kv

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, q_offset=q_offset, seq_kv=skv, num_kv_blocks=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // q_per_kv, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // q_per_kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq, :]
    return jnp.moveaxis(out, 1, 2)
