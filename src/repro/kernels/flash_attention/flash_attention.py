"""Pallas TPU flash-attention forward kernel.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
    innermost, *sequential* ("arbitrary") grid axis, so the fp32 running
    softmax state (acc, m, l) lives in VMEM scratch and persists across kv
    iterations — the TPU grid is executed in order, which replaces the
    CUDA notion of a per-CTA loop over KV tiles.
  * BlockSpec tiles: q (1, 1, block_q, D) and k/v (1, 1, block_kv, D) are
    MXU-aligned (block sizes multiples of 128 where the head dim allows);
    GQA is expressed in the k/v index_map (kv head = q head // q_per_kv)
    so no repeated-KV tensor is ever materialized in HBM.
  * Causal masking is positional (q_offset supports decode/chunked
    prefill); fully-masked kv blocks are skipped via ``pl.when`` so they
    cost a grid tick but no FLOPs.

Validated in interpret mode against ref.mha_dense (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, block_q: int, block_kv: int, causal: bool,
               q_offset: int, seq_kv: int, num_kv_blocks: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block is live unless causal pruning removes it entirely:
    # smallest q position in this block >= largest kv position needed.
    q_start = qb * block_q + q_offset
    kv_start = kb * block_kv
    live = (not causal) or True
    run = jnp.logical_or(jnp.logical_not(jnp.bool_(causal)),
                         q_start + block_q - 1 >= kv_start)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = (q_start +
                jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
        kpos = (kv_start +
                jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1))
        mask = kpos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]                          # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...] * corr[:, None] +
                      jnp.sum(p, axis=-1, keepdims=True))
        acc_ref[...] = (acc_ref[...] * corr[:, None] +
                        jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]

    @pl.when(kb == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,                      # (B, Sq, H, D)
    k: jnp.ndarray,                      # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    q_per_kv = h // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5

    block_q = min(block_q, max(sq, 8))
    block_kv = min(block_kv, max(skv, 8))
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    # (B, S, H, D) -> (B, H, S, D) so the tile is a clean (block, D) matrix
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_q = qt.shape[2] // block_q
    n_kv = kt.shape[2] // block_kv

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, q_offset=q_offset, seq_kv=skv, num_kv_blocks=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // q_per_kv, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // q_per_kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq, :]
    return jnp.moveaxis(out, 1, 2)


# --------------------------------------------------------------------------
# paged flash decode (serving hot path: block-table gather INSIDE the kernel)
# --------------------------------------------------------------------------


def _paged_decode_kernel(tables, lens, q_ref, k_ref, v_ref, o_ref,
                         k_buf, v_buf, *, scale: float, block_size: int,
                         max_blocks: int, null_block: int, heads: int,
                         kv_heads: int, head_dim: int):
    """Grid (B, MB); j sequential. Step j DMAs sequence bi's j-th mapped
    KV block straight from the pool (the block-table lookup happens in
    the BlockSpec index_map via scalar prefetch — no materialized window
    in HBM) into a VMEM-resident dense view; the last step runs the
    reference dense attention on it.

    The final einsums deliberately carry singleton batch/query dims and
    use ref.mha_dense's exact contraction strings: XLA picks a different
    reduction tree for `"hk,khd->hd"` vs `"bhqk,bkhd->bqhd"` (1-ulp
    drift), and the acceptance bar is fp32-BITWISE parity with the
    materialize-then-attend reference.
    """
    bi = pl.program_id(0)
    j = pl.program_id(1)
    q_per_kv = heads // kv_heads
    s_g = max_blocks * block_size

    # NULL (unmapped) blocks were clamped to a real pool slot by the
    # index_map; zero the tile so it matches the reference's
    # `.get(mode="fill", fill_value=0)` gather bit-for-bit.
    is_null = tables[bi, j] == null_block
    k_buf[pl.dslice(j * block_size, block_size)] = jnp.where(
        is_null, 0.0, k_ref[0]).astype(jnp.float32)
    v_buf[pl.dslice(j * block_size, block_size)] = jnp.where(
        is_null, 0.0, v_ref[0]).astype(jnp.float32)

    @pl.when(j == max_blocks - 1)
    def _attend():
        kk = k_buf[...]                               # (s_g, Hkv, D) f32
        vv = v_buf[...]
        q4 = q_ref[0][None]                           # (1, 1, H, D)
        k_rep = jnp.broadcast_to(
            kk[None, :, :, None, :],
            (1, s_g, kv_heads, q_per_kv, head_dim),
        ).reshape(1, s_g, heads, head_dim)
        v_rep = jnp.broadcast_to(
            vv[None, :, :, None, :],
            (1, s_g, kv_heads, q_per_kv, head_dim),
        ).reshape(1, s_g, heads, head_dim)
        s = jnp.einsum("bqhd,bkhd->bhqk", q4.astype(jnp.float32),
                       k_rep) * scale
        mask = jnp.arange(s_g)[None, None, None, :] < lens[bi]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v_rep)
        o_ref[...] = o.astype(o_ref.dtype)


def flash_decode_paged_pallas(
    q: jnp.ndarray,                      # (B, 1, H, D)
    k_pool: jnp.ndarray,                 # (N, bs, Hkv, D)
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,           # (B, MB) int32, NULL == N
    kv_lens: jnp.ndarray,                # (B,) int32 EFFECTIVE lengths
    *,
    softmax_scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-query GQA decode over a paged KV pool, gather in-kernel.

    ``kv_lens`` are the effective context lengths (positions
    ``>= kv_lens[i]`` are masked); the new token's K/V must already be
    scattered into the pool. Returns (B, 1, H, D) in q's dtype, fp32-
    bitwise vs gathering the window with ``mode="fill"`` and running
    ``ref.mha_dense(causal=False, kv_len=kv_lens)``.

    HBM traffic per step is ONE pass over the mapped window (the
    index_map-driven DMA), vs the materialized path's gather-read +
    window-write + attend-read — see benchmarks/serve_bench.py's decode
    roofline for the byte model.
    """
    b, sq, h, d = q.shape
    if sq != 1:
        raise ValueError(f"paged decode expects a single query, got {sq}")
    n_pool, bs, hkv, _ = k_pool.shape
    mb = block_tables.shape[1]
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    s_g = mb * bs

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_size=bs, max_blocks=mb,
        null_block=n_pool, heads=h, kv_heads=hkv, head_dim=d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mb),
        in_specs=[
            pl.BlockSpec((1, 1, h, d),
                         lambda bi, j, tbl, lens: (bi, 0, 0, 0)),
            # block-table indirection lives HERE: the DMA source block is
            # tbl[bi, j] (clamped for NULL; the kernel zeroes those tiles)
            pl.BlockSpec((1, bs, hkv, d),
                         lambda bi, j, tbl, lens: (
                             jnp.minimum(tbl[bi, j], n_pool - 1), 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d),
                         lambda bi, j, tbl, lens: (
                             jnp.minimum(tbl[bi, j], n_pool - 1), 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, d),
                               lambda bi, j, tbl, lens: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((s_g, hkv, d), jnp.float32),   # gathered K view
            pltpu.VMEM((s_g, hkv, d), jnp.float32),   # gathered V view
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_lens.astype(jnp.int32),
      q, k_pool, v_pool)
