"""Mamba2 SSD scan op with implementation dispatch (see ref.py)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.ssd_scan import ref


def ssd_scan(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray, D: Optional[jnp.ndarray] = None,
    *,
    chunk_size: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
    impl: str = "reference",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, final_state)."""
    if impl == "sequential":
        return ref.ssd_sequential(x, dt, A, Bm, Cm, D,
                                  initial_state=initial_state)
    if impl == "reference":
        return ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk_size=chunk_size,
                               initial_state=initial_state)
    if impl == "pallas":
        from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
        return ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk_size=chunk_size,
                               initial_state=initial_state,
                               interpret=interpret)
    raise ValueError(f"unknown ssd impl '{impl}'")


def ssd_decode_step(state, x, dt, A, Bm, Cm, D=None):
    return ref.ssd_decode_step(state, x, dt, A, Bm, Cm, D)
