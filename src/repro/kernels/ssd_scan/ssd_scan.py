"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (Mamba2 paper, listing 1):
  * grid = (batch, head_blocks, chunks); the chunk axis is the innermost
    *sequential* grid axis, and the (block_h, P, N) fp32 SSM state lives
    in VMEM scratch across chunk ticks — the cross-chunk recurrence that
    a GPU implementation does with a separate scan kernel happens for
    free in the TPU grid order.
  * within a chunk everything is dense matmul on the MXU: the (Q, Q)
    intra-chunk kernel L, the (Q, N)x(N, Q) C·Bᵀ Gram matrix, and the
    state in/out projections. Q = chunk_size (default 128/256) and
    N = state_dim are MXU-friendly.
  * B/C group broadcasting (ngroups < heads) is done by the wrapper so
    the kernel sees per-head B/C; the wrapper transposes to head-major
    (B, H, S, ...) so tiles are clean 2-D matrices per head.

Validated in interpret mode against ref.ssd_sequential.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, fin_ref, state_ref, *,
                chunk: int, num_chunks: int, block_h: int,
                head_p: int, state_n: int, use_d: bool):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # (bh, Q, P)
    dt = dt_ref[0, :, :, 0].astype(jnp.float32)  # (bh, Q)
    A = a_ref[...][:, 0].astype(jnp.float32)     # (bh,)
    Bm = b_ref[0].astype(jnp.float32)            # (bh, Q, N)
    Cm = c_ref[0].astype(jnp.float32)            # (bh, Q, N)

    dA_log = dt * A[:, None]                     # (bh, Q)
    A_cum = jnp.cumsum(dA_log, axis=-1)          # inclusive
    # intra-chunk decay kernel: L[h,i,j] = exp(Acum_i - Acum_j), i >= j
    diff = A_cum[:, :, None] - A_cum[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    L = jnp.where(tri[None], jnp.exp(diff), 0.0)  # (bh, Q, Q)

    dx = dt[:, :, None] * x                      # (bh, Q, P)
    # diagonal block: (C Bᵀ ⊙ L) · (dt x)
    G = jax.lax.dot_general(Cm, Bm, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (bh,Q,Q)
    y = jax.lax.dot_general(G * L, dx, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (bh,Q,P)
    # off-diagonal: contribution of the carried state
    state = state_ref[...]                       # (bh, P, N)
    y += jnp.exp(A_cum)[:, :, None] * jax.lax.dot_general(
        Cm, state, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)      # (bh, Q, P)
    if use_d:
        y += x * d_ref[...][:, 0][:, None, None].astype(jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: decayed carry + chunk contribution
    decay_state = jnp.exp(A_cum[:, -1:] - A_cum)  # (bh, Q)
    wdx = decay_state[:, :, None] * dx            # (bh, Q, P)
    chunk_state = jax.lax.dot_general(
        wdx, Bm, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # (bh, P, N)
    state_ref[...] = (jnp.exp(A_cum[:, -1])[:, None, None] * state +
                      chunk_state)

    @pl.when(ci == num_chunks - 1)
    def _finish():
        fin_ref[0] = state_ref[...]


def ssd_scan_pallas(
    x: jnp.ndarray,                    # (B, S, H, P)
    dt: jnp.ndarray,                   # (B, S, H)
    A: jnp.ndarray,                    # (H,)
    Bm: jnp.ndarray,                   # (B, S, G, N)
    Cm: jnp.ndarray,                   # (B, S, G, N)
    D: Optional[jnp.ndarray] = None,   # (H,)
    *,
    chunk_size: int = 128,
    initial_state: Optional[jnp.ndarray] = None,
    block_h: int = 8,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if initial_state is not None:
        raise NotImplementedError(
            "pallas ssd_scan starts from zero state (train/prefill); "
            "decode uses ssd_decode_step")
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    g = Bm.shape[2]
    orig_s = s
    chunk = min(chunk_size, s)
    pad = (-s) % chunk
    block_h = min(block_h, h)
    if h % block_h != 0:
        block_h = 1

    # head-major layout; dt=0 padding keeps state and contributes nothing
    def hm(t):  # (B, S, H, F) -> (B, H, S, F)
        return jnp.moveaxis(t, 2, 1)

    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2) if rep > 1 else Bm
    Ch = jnp.repeat(Cm, rep, axis=2) if rep > 1 else Cm
    xt, Bt, Ct = hm(x), hm(Bh), hm(Ch)
    dtt = hm(dt[..., None])                       # (B, H, S, 1)
    if pad:
        cfgpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        xt = jnp.pad(xt, cfgpad)
        Bt = jnp.pad(Bt, cfgpad)
        Ct = jnp.pad(Ct, cfgpad)
        dtt = jnp.pad(dtt, cfgpad)
    s_p = xt.shape[2]
    nc = s_p // chunk
    nh = h // block_h
    use_d = D is not None
    d_in = (D if use_d else jnp.zeros((h,), jnp.float32))[:, None]

    kernel = functools.partial(
        _ssd_kernel, chunk=chunk, num_chunks=nc, block_h=block_h,
        head_p=p, state_n=n, use_d=use_d)

    y, fin = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, block_h, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, 1),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((block_h, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, block_h, chunk, n),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk, n),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((block_h, 1), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_h, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, p, n),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s_p, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, p, n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32)[:, None], Bt, Ct,
      d_in.astype(jnp.float32))
    y = jnp.moveaxis(y[:, :, :orig_s, :], 1, 2)   # back to (B, S, H, P)
    return y, fin
