"""Pure-jnp oracles for the Mamba2 SSD (state-space dual) scan.

Layouts:
  x  (B, S, H, P)   channels grouped into H heads of dim P
  dt (B, S, H)      post-softplus step sizes
  A  (H,)           negative per-head decay (A < 0)
  Bm (B, S, G, N)   input->state projection, G groups broadcast over heads
  Cm (B, S, G, N)   state->output projection
  D  (H,) or None   skip connection
State: (B, H, P, N).

``ssd_sequential`` is the direct recurrence (ground truth for tests).
``ssd_chunked`` is the chunked SSD algorithm (Mamba2 paper, listing 1) —
identical math, O(S/Q) sequential steps; the model lowers this on CPU and
the Pallas kernel (ssd_scan.py) implements it with VMEM-tiled chunks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _broadcast_groups(m: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """(B, S, G, N) -> (B, S, H, N)."""
    b, s, g, n = m.shape
    rep = num_heads // g
    if rep == 1:
        return m
    m = jnp.broadcast_to(m[:, :, :, None, :], (b, s, g, rep, n))
    return m.reshape(b, s, num_heads, n)


def ssd_sequential(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray, D: Optional[jnp.ndarray] = None,
    initial_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Bm = _broadcast_groups(Bm, h).astype(jnp.float32)
    Cm = _broadcast_groups(Cm, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, None, :])            # (B, S, H)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))

    def step(state, inp):
        xt, dat, dtt, bt, ct = inp                   # per-time slices
        dbx = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        state = dat[:, :, None, None] * state + dbx
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (xf.transpose(1, 0, 2, 3), dA.transpose(1, 0, 2),
          dtf.transpose(1, 0, 2), Bm.transpose(1, 0, 2, 3),
          Cm.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                     # (B, S, H, P)
    if D is not None:
        y = y + xf * D[None, None, :, None]
    return y.astype(x.dtype), final


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums.

    out[..., i, j] = sum(a[..., j+1 : i+1]) for i >= j, -inf otherwise.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray, D: Optional[jnp.ndarray] = None,
    *,
    chunk_size: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    orig_s = s
    q = min(chunk_size, s)
    if s % q != 0:
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    c = s // q

    Bf = _broadcast_groups(Bm, h).astype(jnp.float32).reshape(b, c, q, h, n)
    Cf = _broadcast_groups(Cm, h).astype(jnp.float32).reshape(b, c, q, h, n)
    xf = x.astype(jnp.float32).reshape(b, c, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, c, q, h)
    dA_log = dtf * A[None, None, None, :]            # (B, C, Q, H)
    dA_log = dA_log.transpose(0, 3, 1, 2)            # (B, H, C, Q)
    A_cum = jnp.cumsum(dA_log, axis=-1)              # (B, H, C, Q)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA_log))                     # (B, H, C, Q, Q)
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cf, Bf, L, dtf[..., None] * xf)

    # 2) per-chunk final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)  # (B, H, C, Q)
    chunk_states = jnp.einsum("bcqhn,bhcq,bcqhp->bchpn",
                              Bf, decay_states, dtf[..., None] * xf)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(A_cum[..., -1])            # (B, H, C)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))

    def chunk_step(state, inp):
        st_c, dec_c = inp                            # (B,H,P,N), (B,H)
        prev = state
        state = dec_c[:, :, None, None] * state + st_c
        return state, prev

    xs = (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1))
    final, prev_states = jax.lax.scan(chunk_step, h0, xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B, C, H, P, N)

    # 4) inter-chunk (off-diagonal) output contribution
    state_decay_out = jnp.exp(A_cum)                 # (B, H, C, Q)
    Y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       Cf, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, h, p)[:, :orig_s]
    if D is not None:
        y = y + x.astype(jnp.float32)[:, :orig_s] * D[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jnp.ndarray,        # (B, H, P, N)
    x: jnp.ndarray,            # (B, H, P) one token
    dt: jnp.ndarray,           # (B, H)
    A: jnp.ndarray,            # (H,)
    Bm: jnp.ndarray,           # (B, G, N)
    Cm: jnp.ndarray,           # (B, G, N)
    D: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, hh, p, n = state.shape
    g = Bm.shape[1]
    rep = hh // g
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])                   # (B, H)
    dbx = jnp.einsum("bh,bhp,bhn->bhpn", dtf, xf, Bf)
    state = dA[:, :, None, None] * state.astype(jnp.float32) + dbx
    y = jnp.einsum("bhpn,bhn->bhp", state, Cf)
    if D is not None:
        y = y + xf * D[None, :, None]
    return y.astype(x.dtype), state
