"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000; llama2-arch small [arXiv:2401.02385; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        d_ff=5632, vocab_size=32000,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=160, vocab_size=256,
        norm="rmsnorm", activation="swiglu", remat="none",
    )


register("tinyllama-1.1b", full, smoke)
