"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552; RoPE, GQA [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=151552,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=224, vocab_size=512,
        norm="rmsnorm", activation="swiglu", remat="none",
    )


register("glm4-9b", full, smoke)
