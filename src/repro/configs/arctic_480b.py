"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000; 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Arctic's dense-MoE hybrid: every layer runs a small dense FFN residual
branch *in parallel* with the 128-expert top-2 MoE (``dense_residual``).
Optimizer-state dtype is reduced (bf16 m) so ZeRO-1-sharded Adam state
fits 16 GB HBM on the single-pod mesh — noted in EXPERIMENTS.md.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                      dense_residual=True),
        # 480e9 fp32 params alone are 7.5 GB/chip on 256 chips; bf16
        # params + bf16 moments (configs.base.optimizer_for) fit 16 GB
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        norm="rmsnorm", activation="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=96,
                      dense_residual=True),
        remat="none",
    )


register("arctic-480b", full, smoke)
