"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064; RoPE SwiGLU GQA [arXiv:2412.08905; hf].

The 200k vocabulary makes the lm-head/CE path the dominant activation;
this arch is the motivating case for kernels/cross_entropy.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=200064,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b-smoke", family="dense",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=256, vocab_size=1024,
        norm="rmsnorm", activation="swiglu", tie_embeddings=True,
        remat="none",
    )


register("phi4-mini-3.8b", full, smoke)
