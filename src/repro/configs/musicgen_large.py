"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec tokenizer + codebook-interleaving frontend is
a STUB — input_specs() provides precomputed (summed-codebook) frame
embeddings. The output head predicts one 2048-entry codebook.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        norm="layernorm", activation="gelu", rope_theta=10000.0,
        frontend="embedding_stub",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=192, vocab_size=128,
        norm="layernorm", activation="gelu",
        frontend="embedding_stub", remat="none",
    )


register("musicgen-large", full, smoke)
