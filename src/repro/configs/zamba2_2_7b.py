"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + weight-shared attention
block every 6 layers [arXiv:2411.15242; hf].

Sub-quadratic: runs the long_500k cell (Mamba2 state is O(1) per token;
the shared attention block uses a KV cache — O(S) per decoded token).
"""
from repro.configs.base import (HybridConfig, ModelConfig, SSMConfig,
                                register)


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=10240, vocab_size=32000,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4,
                      chunk_size=256, ngroups=1),
        hybrid=HybridConfig(enabled=True, attn_every=6,
                            shared_attn_d_ff=10240),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", activation="swiglu",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk_size=32, ngroups=1),
        hybrid=HybridConfig(enabled=True, attn_every=2,
                            shared_attn_d_ff=128),
        remat="none",
    )


register("zamba2-2.7b", full, smoke)
