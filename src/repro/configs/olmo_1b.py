"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192
vocab=50304; non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304,
        norm="nonparam_ln", activation="swiglu", rope_theta=10000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        norm="nonparam_ln", activation="swiglu", tie_embeddings=True,
        remat="none",
    )


register("olmo-1b", full, smoke)
