"""Config system for the HetSeq-JAX framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; shapes
(train_4k / prefill_32k / decode_32k / long_500k) are :class:`ShapeConfig`;
the heterogeneous-capacity training setup (the paper's contribution) is a
:class:`HetConfig`.  ``resolve(arch_id)`` returns the registered full config,
``smoke_config(arch_id)`` a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard-style top-k routing)."""

    num_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0            # FFN hidden size inside each expert
    num_shared_experts: int = 0     # DeepSeek-style always-on experts
    shared_d_ff: int = 0            # hidden size of the shared expert(s)
    dense_residual: bool = False    # Arctic-style parallel dense FFN branch
    capacity_factor: float = 1.25   # per-device expert capacity multiplier
    capacity_factor_eval: float = 2.0  # prefill/eval: generous, fewer drops
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01     # load-balancing auxiliary loss

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention settings."""

    kv_lora_rank: int = 0           # latent dim for compressed KV
    q_lora_rank: int = 0            # 0 => dense q projection
    rope_head_dim: int = 64         # decoupled RoPE dims (shared across heads)
    nope_head_dim: int = 128        # per-head non-RoPE dims
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings for hybrid / ssm architectures."""

    state_dim: int = 0              # N: per-head SSM state size (0 => off)
    head_dim: int = 64              # P: channels per SSM head
    num_heads: int = 0              # 0 => derived from d_inner / head_dim
    expand: int = 2                 # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 256           # SSD chunked-scan block length
    ngroups: int = 1                # B/C groups (GVA-style)

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block-stack settings (alternating mLSTM / sLSTM blocks)."""

    enabled: bool = False
    num_heads: int = 4
    slstm_every: int = 2            # every k-th block is an sLSTM block
    proj_factor_mlstm: float = 2.0  # mLSTM up-projection factor
    proj_factor_slstm: float = 1.333  # post-sLSTM gated FFN factor
    conv_kernel: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + weight-shared attention block."""

    enabled: bool = False
    attn_every: int = 6             # shared attention applied every k layers
    shared_attn_d_ff: int = 0       # FFN inside the shared block (0 = none)


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-only backbone configuration (LM family)."""

    name: str = "unnamed"
    family: str = "dense"           # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    d_ff: int = 3072                # dense FFN hidden (0 => no FFN sub-block)
    vocab_size: int = 50304
    head_dim: int = 0               # 0 => d_model // num_heads
    max_seq_len: int = 4096

    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparam_ln
    activation: str = "swiglu"      # swiglu | gelu | geglu
    rope_theta: float = 10000.0
    qk_norm: bool = False           # Chameleon-style query/key norm
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: XLSTMConfig = field(default_factory=XLSTMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)

    # modality frontend stubs ([vlm]/[audio]): input_specs() provides
    # precomputed frame/patch embeddings instead of token ids.
    frontend: str = "token"         # token | embedding_stub

    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full
    scan_layers: bool = True
    attention_impl: str = "reference"   # see ATTENTION_IMPLS

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.attention_impl not in ATTENTION_IMPLS:
            raise ValueError(
                f"attention_impl must be one of {ATTENTION_IMPLS}, got "
                f"'{self.attention_impl}'")

    # ---- derived quantities -------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Total parameter count (analytic, matches init_params)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE counts top_k + shared experts)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is supported (SSM/hybrid families)."""
        return self.ssm.enabled or self.xlstm.enabled


# --------------------------------------------------------------------------
# Shapes (assigned input-shape set for the LM family)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{model.name} is pure full-attention (skip noted in DESIGN.md)"
        )
    return True, ""


# --------------------------------------------------------------------------
# Heterogeneous-capacity (the paper's technique) configuration
# --------------------------------------------------------------------------


# Accepted values of the HetConfig mode fields. These constants are the
# single source of truth: HetConfig.validate() checks membership,
# launch/steps.py derives its config checks from them, launch/train.py
# exposes them as CLI choices, and tests/test_config_docs.py asserts the
# README config matrix agrees with them.
GRAD_REDUCTION_MODES = ("allreduce", "bucketed_allreduce", "hierarchical")
OVERLAP_MODES = ("none", "buckets", "backward")
COMPRESSION_MODES = ("none", "int8")
QUANTIZE_IMPLS = ("reference", "pallas")
# ModelConfig.attention_impl: selects the attention kernels on BOTH hot
# paths — train/prefill flash attention (models/blocks.py) and the paged
# decode kernels on the serving path (models/kvcache.py). "reference" is
# the portable jnp path ("dense" forces the full-score-matrix oracle);
# "pallas" selects the fused TPU kernels, falling back LOUDLY to
# interpret mode where the backend can't compile Pallas
# (compat.pallas_interpret_fallback).
ATTENTION_IMPLS = ("reference", "dense", "pallas")
WEIGHTING_MODES = ("tokens", "samples", "canonical")
PIPELINE_MODES = ("1f1b", "gpipe")

# Which grad_reduction modes the overlap pipelines schedule: overlap is
# a schedule OF the explicit bucketed engine, so it needs one of these
# plus bucket_mb > 0.
EXPLICIT_REDUCTIONS = ("bucketed_allreduce", "hierarchical")


@dataclass(frozen=True)
class HetConfig:
    """HetSeq heterogeneous data-parallel settings.

    Fields (one line each — valid values and interactions; see
    docs/architecture.md for the full narrative):

    ``capacities``: relative throughput per DP rank (pod x data
        position); empty tuple = homogeneous. The capacity planner
        turns these into per-rank real-row counts, remaining buffer
        rows are weight-0 dummies (paper M1/M3).
    ``weighting``: "tokens" | "samples" — what a unit of loss weight
        counts (paper M3 aggregation contract) — or "canonical": the
        order-canonical executor (core/weighting.py) — per-row vmapped
        gradients summed in global-row order with one fixed reduction
        tree, so the step is bit-identical across capacity replans;
        costs per-row grads and requires grad_reduction="allreduce",
        overlap="none", compression="none", accum_steps=1.
    ``grad_reduction``: "allreduce" (paper-faithful, XLA-automatic) |
        "bucketed_allreduce" (explicit flat-buffer reduction over the
        DP axes; requires ``bucket_mb > 0``) | "hierarchical" (in-pod
        automatic over ICI, cross-pod DCN leg explicit, optionally
        compressed; bucketed when ``bucket_mb > 0``).
    ``compression``: "none" | "int8" — cross-pod payload encoding;
        only consulted by "hierarchical" (other modes reduce fp32).
    ``error_feedback``: keep per-rank residuals of the int8 quantizer
        (both stages) and fold them into the next step; only active for
        hierarchical + int8 on a multi-pod mesh.
    ``bucket_mb``: bucket payload in MiB of f32 for the bucketed
        engine (PyTorch-DDP-style knob); 0 keeps the legacy per-leaf
        walk and is invalid with "bucketed_allreduce" or any overlap.
    ``quantize_impl``: "reference" (pure jnp, portable) | "pallas"
        (fused TPU kernels) for the int8 exchange kernels.
    ``overlap``: "none" (monolithic: pack -> 2 collectives -> unpack
        -> tree-wide update) | "buckets" (double-buffered per-bucket
        pipeline fused with flat-view optimizer updates, after the
        backward pass) | "backward" (beyond "buckets": buckets flush
        DURING backprop as their last contributing layer's cotangent
        lands; requires ``ModelConfig.scan_layers=False`` and a
        uniform-stack architecture). Both pipelines require an
        explicit ``grad_reduction`` and ``bucket_mb > 0``; global-norm
        clipping and LAMB keep the pipelined exchange but update
        behind a barrier.
    ``accum_steps``: gradient-accumulation microbatch count (paper M4
        delayed update); >= 1. With ``pipeline_stages > 1`` the
        microbatches ARE the pipeline's 1F1B stream, so
        ``accum_steps >= pipeline_stages`` (the pipe must fill).
    ``straggler_ema``: EMA decay of per-rank step-time tracking in
        [0, 1) (core/straggler.py).
    ``replan_interval``: steps between soft capacity replans; >= 1.
    ``pipeline_stages``: contiguous layer-stack stages (core/pipeline.py
        StagePlan, sized by per-pod capacity scores); 1 = no pipelining.
        > 1 requires a uniform-stack architecture with
        ``scan_layers=False`` (checked at build time), overlap="none"
        (the overlap pipelines flush buckets over the DP axes
        mid-backward, which cannot cross a stage boundary),
        weighting != "canonical", and grad_reduction "allreduce" or
        "bucketed_allreduce".
    ``pipeline_schedule``: "1f1b" (warmup / steady 1F1B / drain) |
        "gpipe" (all forwards then all backwards); see PIPELINE_MODES.
    """

    capacities: Tuple[float, ...] = ()      # empty => homogeneous
    weighting: str = "tokens"               # tokens | samples
    grad_reduction: str = "allreduce"       # see GRAD_REDUCTION_MODES
    compression: str = "none"               # see COMPRESSION_MODES
    error_feedback: bool = True
    bucket_mb: float = 0.0                  # >0 => bucketed flat-buffer engine
    quantize_impl: str = "reference"        # see QUANTIZE_IMPLS
    overlap: str = "none"                   # see OVERLAP_MODES
    accum_steps: int = 1                    # delayed update (paper M4)
    straggler_ema: float = 0.9
    replan_interval: int = 100              # steps between capacity replans
    pipeline_stages: int = 1                # >1 => pipelined layer stack
    pipeline_schedule: str = "1f1b"         # see PIPELINE_MODES

    def validate(self) -> "HetConfig":
        """Mesh-independent config validation. Raises ``ValueError``
        with an actionable message instead of failing deep in the
        pipeline; mesh/model-dependent checks (reduction axes, stack
        plan, scan_layers) live in ``launch/steps.py`` and run at
        ``build_train_step`` time. Returns self for chaining."""
        def member(name, value, allowed):
            if value not in allowed:
                raise ValueError(
                    f"HetConfig.{name}='{value}' is not one of "
                    f"{' | '.join(allowed)}")

        member("weighting", self.weighting, WEIGHTING_MODES)
        member("grad_reduction", self.grad_reduction, GRAD_REDUCTION_MODES)
        member("compression", self.compression, COMPRESSION_MODES)
        member("quantize_impl", self.quantize_impl, QUANTIZE_IMPLS)
        member("overlap", self.overlap, OVERLAP_MODES)
        member("pipeline_schedule", self.pipeline_schedule, PIPELINE_MODES)
        if self.pipeline_stages < 1:
            raise ValueError(
                f"HetConfig.pipeline_stages must be >= 1, got "
                f"{self.pipeline_stages}")
        if self.bucket_mb < 0:
            raise ValueError(
                f"HetConfig.bucket_mb must be >= 0, got {self.bucket_mb}")
        if self.accum_steps < 1:
            raise ValueError(
                f"HetConfig.accum_steps must be >= 1, got "
                f"{self.accum_steps}")
        if not 0.0 <= self.straggler_ema < 1.0:
            raise ValueError(
                f"HetConfig.straggler_ema must be in [0, 1), got "
                f"{self.straggler_ema}")
        if self.replan_interval < 1:
            raise ValueError(
                f"HetConfig.replan_interval must be >= 1, got "
                f"{self.replan_interval}")
        if any(c < 0 for c in self.capacities):
            raise ValueError(
                f"HetConfig.capacities must be non-negative, got "
                f"{self.capacities}")
        if self.grad_reduction == "bucketed_allreduce" \
                and self.bucket_mb <= 0:
            raise ValueError(
                "HetConfig.grad_reduction='bucketed_allreduce' needs "
                "bucket_mb > 0 (the explicit flat-buffer engine)")
        if self.overlap != "none":
            if self.grad_reduction not in EXPLICIT_REDUCTIONS:
                raise ValueError(
                    f"HetConfig.overlap='{self.overlap}' needs an "
                    f"explicit reduction "
                    f"({' | '.join(EXPLICIT_REDUCTIONS)}), not "
                    f"'{self.grad_reduction}'")
            if self.bucket_mb <= 0:
                raise ValueError(
                    f"HetConfig.overlap='{self.overlap}' needs "
                    f"bucket_mb > 0 (a bucket grid to pipeline over)")
        if self.weighting == "canonical":
            # one fixed reduction tree over global rows — any engine
            # that regroups the sum (buckets, hierarchy, compression,
            # accumulation) would break the bit-identity guarantee
            for field, value, want in (
                    ("grad_reduction", self.grad_reduction, "allreduce"),
                    ("overlap", self.overlap, "none"),
                    ("compression", self.compression, "none")):
                if value != want:
                    raise ValueError(
                        f"HetConfig.weighting='canonical' requires "
                        f"{field}='{want}', got '{value}' (the "
                        f"order-canonical sum must be the only "
                        f"reduction)")
            if self.accum_steps != 1:
                raise ValueError(
                    "HetConfig.weighting='canonical' requires "
                    f"accum_steps=1, got {self.accum_steps}")
        if self.pipeline_stages > 1:
            if self.overlap != "none":
                raise ValueError(
                    f"HetConfig.overlap='{self.overlap}' is incompatible "
                    f"with pipeline_stages={self.pipeline_stages}: the "
                    "overlap pipelines flush grad buckets over the DP "
                    "axes mid-backward, which cannot cross a pipeline "
                    "stage boundary (each stage owns only its layer "
                    "slice); use overlap='none' — the pipeline step "
                    "already reduces grads per-stage")
            if self.weighting == "canonical":
                raise ValueError(
                    "HetConfig.weighting='canonical' is incompatible "
                    f"with pipeline_stages={self.pipeline_stages}: the "
                    "order-canonical executor needs one fixed "
                    "whole-model reduction tree, but 1F1B regroups the "
                    "sum per (stage, microbatch)")
            if self.grad_reduction == "hierarchical":
                raise ValueError(
                    "HetConfig.grad_reduction='hierarchical' is not "
                    f"supported with pipeline_stages="
                    f"{self.pipeline_stages}; use 'allreduce' or "
                    "'bucketed_allreduce' (per-stage bucket flush)")
            if self.accum_steps < self.pipeline_stages:
                raise ValueError(
                    f"HetConfig.pipeline_stages={self.pipeline_stages} "
                    f"needs accum_steps >= pipeline_stages (got "
                    f"{self.accum_steps}): the accumulation microbatches "
                    "are the 1F1B stream and the pipe must fill")
        return self


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.98)    # paper: transformer betas
    eps: float = 1e-9
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    schedule: str = "inverse_sqrt"              # inverse_sqrt | linear | cosine | constant
    warmup_steps: int = 4000
    total_steps: int = 100000
    m_dtype: str = "float32"
    v_dtype: str = "float32"


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh. DP spans (pod, data); TP/EP/SP use model."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp_size(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("pod", "data"):
                n *= s
        return n

    @property
    def model_size(self) -> int:
        for ax, s in zip(self.axes, self.shape):
            if ax == "model":
                return s
        return 1


@dataclass(frozen=True)
class TrainConfig:
    """One full training-run configuration.

    Fields (one line each):

    ``model``: the :class:`ModelConfig` backbone being trained.
    ``shape``: the (seq_len, global_batch) training cell; kind "train".
    ``het``: the :class:`HetConfig` heterogeneous-DP settings — run
        ``het.validate()`` / ``build_train_step`` for the interaction
        rules (overlap needs bucket_mb > 0, "backward" additionally
        needs ``model.scan_layers=False`` and a uniform stack, ...).
    ``optimizer``: :class:`OptimizerConfig`; name "adamw" | "lamb"
        (LAMB and ``grad_clip > 0`` force the overlap pipelines onto
        the barrier update path).
    ``mesh``: logical mesh description; DP spans (pod, data), TP uses
        "model".
    ``seed``: global RNG seed — one key IS the broadcast (paper M8).
    ``zero1``: shard optimizer state over DP like params (beyond
        paper); ignored by the overlap modes (packed moments are
        replicated over the reduction axes).
    ``label_smoothing``: CE label smoothing in [0, 1); the paper's
        translation task uses 0.1.
    ``log_every``: steps between progress log lines; >= 1.
    ``ckpt_every``: steps between checkpoints; 0 disables periodic
        saves (a final save still happens in the driver).
    ``ckpt_dir``: checkpoint directory (versioned step_<N> subdirs).
    ``ckpt_keep``: checkpoints retained by rotation; 0 keeps all.
    """

    model: ModelConfig
    shape: ShapeConfig = TRAIN_4K
    het: HetConfig = field(default_factory=HetConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    seed: int = 0
    zero1: bool = True              # shard optimizer state over DP (beyond paper)
    label_smoothing: float = 0.0    # paper translation task uses 0.1
    log_every: int = 10
    ckpt_every: int = 1000
    ckpt_dir: str = "/tmp/hetseq_ckpt"
    ckpt_keep: int = 3


def accum_for(model: ModelConfig, multi_pod: bool = False) -> int:
    """Per-arch gradient-accumulation (paper M4, delayed update) policy
    for the production train_4k cell.

    Activation temps scale with per-microbatch tokens; the large-d /
    MoE-giant cells need accumulation to fit 16 GB HBM per chip. The
    microbatch must still give every DP rank >= 1 row:
    256 rows / 32 ranks (multi-pod) caps accum at 8 there.

    NOTE: the CPU dry-run backend legalizes bf16 GEMMs to f32 (operand
    copies), inflating measured activation memory ~2x vs real TPU; the
    accum chosen here fits even that pessimistic bound (EXPERIMENTS.md).
    """
    policy = {
        "chameleon-34b": (4, 4),         # (single-pod, multi-pod)
        "glm4-9b": (2, 2),
        "phi4-mini-3.8b": (2, 2),
        "deepseek-v2-236b": (8, 8),
        "arctic-480b": (16, 8),
        "zamba2-2.7b": (2, 2),
        "musicgen-large": (2, 2),
    }.get(model.name, (1, 1))
    return policy[1 if multi_pod else 0]


def optimizer_for(model: ModelConfig, **overrides) -> OptimizerConfig:
    """Per-architecture optimizer-state dtype policy.

    The two MoE giants cannot hold fp32 Adam moments on 256 x 16 GB:
      arctic-480b      : 480e9 x 12 B (fp32 p+m+v) / 256 = 22.5 GB/chip.
                         bf16 p+m+v => 11.25 GB/chip (documented in
                         EXPERIMENTS.md; stochastic-rounding-free bf16 m/v
                         is the standard large-MoE compromise).
      deepseek-v2-236b : fp32 params + bf16 m/v => 7.4 GB/chip.
    Everything else keeps full fp32 state.
    """
    policy = {
        "arctic-480b": {"m_dtype": "bfloat16", "v_dtype": "bfloat16"},
        "deepseek-v2-236b": {"m_dtype": "bfloat16", "v_dtype": "bfloat16"},
    }.get(model.name, {})
    policy.update(overrides)
    return OptimizerConfig(**policy)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def resolve(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def smoke_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[arch_id]()


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # import all arch config modules for their register() side effects
    from repro.configs import (  # noqa: F401
        olmo_1b, tinyllama_1_1b, glm4_9b, phi4_mini_3_8b, chameleon_34b,
        arctic_480b, deepseek_v2_236b, zamba2_2_7b, musicgen_large, xlstm_125m,
    )
    _LOADED = True
