"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 vocab=102400;
MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434; hf].

Multi-head latent attention compresses KV into a rank-512 latent
(+ a shared 64-dim decoupled RoPE key); decode attends in latent space
(absorbed W_uk/W_uv — models/kvcache.py) so the cache is ~576 per token
instead of 2*128*192.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=1536, vocab_size=102400, head_dim=192,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                      num_shared_experts=2, shared_d_ff=1536),
        # 236e9 fp32 params + fp32 Adam moments do not fit 256 x 16 GB;
        # bf16 params + bf16 moments (configs.base.optimizer_for) do
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256, head_dim=48,
        norm="rmsnorm", activation="swiglu",
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      rope_head_dim=16, nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=96,
                      num_shared_experts=1, shared_d_ff=96),
        remat="none",
    )


register("deepseek-v2-236b", full, smoke)
