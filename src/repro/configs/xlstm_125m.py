"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304;
alternating sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: no separate FFN sub-block — the mLSTM block carries an internal
2x up-projection and the sLSTM block a gated 4/3x post-FFN (paper
design). Fully recurrent: runs the long_500k cell with O(1) state.
"""
from repro.configs.base import ModelConfig, XLSTMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        norm="layernorm",
        xlstm=XLSTMConfig(enabled=True, num_heads=4, slstm_every=2,
                          proj_factor_mlstm=2.0, proj_factor_slstm=1.333,
                          conv_kernel=4),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=256,
        norm="layernorm",
        xlstm=XLSTMConfig(enabled=True, num_heads=2, slstm_every=2,
                          proj_factor_mlstm=2.0, proj_factor_slstm=1.333,
                          conv_kernel=4),
        remat="none",
    )


register("xlstm-125m", full, smoke)
