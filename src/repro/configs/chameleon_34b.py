"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion, VQ image tokens [arXiv:2405.09818; unverified].

Backbone only: the VQ-VAE image tokenizer frontend is a STUB —
input_specs() provides precomputed patch/token embeddings (B, S, d).
QK-norm per the Chameleon paper (training-stability fix).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=65536,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        qk_norm=True, frontend="embedding_stub",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=192, vocab_size=512,
        norm="rmsnorm", activation="swiglu", qk_norm=True,
        frontend="embedding_stub", remat="none",
    )


register("chameleon-34b", full, smoke)
