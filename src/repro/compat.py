"""Version compatibility for the jax public API surface we use.

The codebase is written against the current jax API (``jax.shard_map``
with ``axis_names=``, ``jax.set_mesh``, keyword ``AbstractMesh``).
Older jaxlibs (0.4.x, as baked into some containers) expose the same
functionality under ``jax.experimental.shard_map`` with an ``auto=``
complement set and context-manager meshes. Routing every call through
this module keeps the rest of the tree version-agnostic.

Nothing here changes semantics: ``shard_map(axis_names=S)`` always
means "axes in S are manual, every other mesh axis stays automatic".

Manual collectives: old jaxlib's SPMD partitioner aborts (hard C++
check-fail, not a catchable error) on ``all_gather`` / ``all_to_all``
inside a *partially*-manual region (manual subset of axes, the rest
auto). ``manual_all_gather`` / ``manual_all_to_all`` below route to the
native primitives on current jax and fall back to a psum-based
emulation otherwise: mask-into-zeros + psum is mathematically an
all-gather, and gather-then-select is an all-to-all. The emulation
keeps the collective *count* identical (one psum per call) but moves
full-buffer bytes; the analytic byte models in core/buckets.py describe
the native schedule, which is what runs on real multi-host deployments.
"""
from __future__ import annotations

import logging
from typing import Any, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

logger = logging.getLogger(__name__)

AxisNames = Union[str, Tuple[str, ...]]

# native all_gather/all_to_all inside partially-manual shard_map regions
# only work on the current-API jax (see module docstring)
NATIVE_MANUAL_COLLECTIVES = hasattr(jax, "shard_map")

# Backends whose Pallas pipeline can *compile* pallas_call. The CPU
# backend on the compat jaxlib raises at lowering time ("Only interpret
# mode is supported on CPU backend"), so kernels selected via
# ``attention_impl="pallas"`` / ``quantize_impl="pallas"`` must run in
# interpret mode there — same numerics, no fused-kernel perf.
PALLAS_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")

_warned_pallas_fallbacks: Set[str] = set()


def pallas_interpret_fallback(what: str) -> bool:
    """True when Pallas kernels must run interpreted on this backend.

    The fallback is LOUD, not silent: the first call per ``what`` logs a
    warning that the requested kernel path still runs (same numerics,
    the parity tests stay meaningful) but without the fused-kernel
    performance, so a serving deployment on the wrong backend cannot
    quietly think it is getting the in-kernel block gather. Mirrors the
    ``quantize_impl`` precedent: the knob keeps meaning "pallas", only
    the execution mode degrades.
    """
    if jax.default_backend() in PALLAS_COMPILED_BACKENDS:
        return False
    if what not in _warned_pallas_fallbacks:
        _warned_pallas_fallbacks.add(what)
        logger.warning(
            "%s: backend %r cannot compile Pallas kernels; running the "
            "pallas path in interpret mode (numerics preserved, fused-"
            "kernel performance lost). Deploy on a TPU/GPU backend for "
            "the compiled kernel.", what, jax.default_backend())
    return True

# Sharding-invariant RNG: current jax defaults this on; old versions
# default off, making jax.random values depend on the OUTPUT SHARDING
# of the jitted computation. The M8 invariant (one global key IS the
# broadcast — identical init on every mesh, and identical across
# reduction modes whose param specs differ) requires it.
try:
    jax.config.update("jax_threefry_partitionable", True)
except AttributeError:                                 # future removal
    pass


def _ambient_mesh() -> Mesh:
    """The mesh installed by ``set_mesh`` (old-API fallback path)."""
    from jax._src.mesh import thread_resources

    m = thread_resources.env.physical_mesh
    if m.empty:
        raise RuntimeError(
            "shard_map(mesh=None) needs an ambient mesh; wrap the call "
            "in `with compat.set_mesh(mesh):`")
    return m


def shard_map(f, *, mesh: Optional[Mesh] = None, in_specs: Any,
              out_specs: Any, axis_names: Optional[Set[str]] = None,
              check_vma: bool = False):
    """``jax.shard_map`` with manual ``axis_names``, on any jax version.

    ``axis_names=None`` means every mesh axis is manual (the jax
    default); ``mesh=None`` uses the ambient mesh from ``set_mesh``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    m = mesh if mesh is not None else _ambient_mesh()
    auto = (frozenset() if axis_names is None
            else frozenset(m.axis_names) - set(axis_names))
    return _sm(f, mesh=m, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def set_mesh(mesh: Mesh):
    """Context manager making ``mesh`` ambient (jax.set_mesh analogue).

    On old jax a ``Mesh`` is itself the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``AbstractMesh`` across the keyword/positional signature change."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(tuple(axes), tuple(shape))))


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def pad_trailing(x: jnp.ndarray, pad: int) -> jnp.ndarray:
    """Zero-pad the LAST axis, safe inside manual shard_map regions.

    Old partitioners check-fail on the HLO Pad op inside partially-
    manual regions; a concat of zeros lowers cleanly and is identical.
    No-op (and no HLO emitted) when ``pad == 0``.
    """
    if pad == 0:
        return x
    z = jnp.zeros(x.shape[:-1] + (pad,), x.dtype)
    return jnp.concatenate([x, z], axis=-1)


# --------------------------------------------------------------------------
# manual-region collectives (inside shard_map)
# --------------------------------------------------------------------------


def manual_axis_onehot(axis: AxisNames, axis_size: int,
                       tie: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(axis_size,) f32 one-hot of this rank's linearized position.

    The linearization is *defined by psum_scatter's scatter order* —
    derived by reduce-scattering an identity matrix — so entry ``i`` of
    a ``psum_scatter`` over ``axis`` lands on the rank whose one-hot is
    ``e_i``. This self-consistency is what the bucketed reduction's
    owner-shard bookkeeping relies on; it also sidesteps
    ``axis_index``'s unsupported PartitionId lowering inside
    partially-manual regions on old jaxlibs.

    ``tie``: any traced array from the region's inputs. Old partitioners
    also check-fail on collectives over *constants* in partially-manual
    regions; adding ``0 * tie`` routes the identity through the input
    lattice. Pass it whenever one is at hand.

    On current jax this is collective-free (``axis_index`` lowers
    natively, and its linearization over named axes matches
    psum_scatter's scatter order); the identity reduce-scatter only
    runs on the old-jax emulation path where ``axis_index`` cannot
    lower.
    """
    if NATIVE_MANUAL_COLLECTIVES:
        idx = jax.lax.axis_index(axis)
        return jax.nn.one_hot(idx, axis_size, dtype=jnp.float32)
    eye = jnp.eye(axis_size, dtype=jnp.float32)
    if tie is not None:
        eye = eye + jnp.zeros((), jnp.float32) * \
            tie.reshape(-1)[0].astype(jnp.float32)
    return jax.lax.psum_scatter(eye, axis, scatter_dimension=0,
                                tiled=False) / axis_size


def manual_axis_index(axis: AxisNames, axis_size: int,
                      tie: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Linearized (scatter-ordered) rank index over the manual axes."""
    return jnp.argmax(
        manual_axis_onehot(axis, axis_size, tie)).astype(jnp.int32)


def manual_all_gather(x: jnp.ndarray, axis: AxisNames, axis_size: int,
                      onehot: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """all_gather inside a (partially-)manual region -> (axis_size, *x).

    Stacks every rank's ``x`` along a new leading axis in psum_scatter
    rank order (the ``tiled=False`` all_gather layout). ``axis_size``
    must be the static total size of ``axis``. ``onehot``: pass a
    precomputed ``manual_axis_onehot`` to share the rank-derivation
    scatter between several emulated collectives.
    """
    if NATIVE_MANUAL_COLLECTIVES:
        return jax.lax.all_gather(x, axis, axis=0, tiled=False)
    # emulation: mask the local shard into its slot, then psum
    if onehot is None:
        onehot = manual_axis_onehot(axis, axis_size, tie=x)
    mask = onehot.reshape((axis_size,) + (1,) * x.ndim)
    wide = jnp.float32 if x.dtype == jnp.int8 else x.dtype
    out = jax.lax.psum(mask * x[None].astype(wide), axis)
    return out.astype(x.dtype)


def manual_all_to_all(x: jnp.ndarray, axis: AxisNames, axis_size: int,
                      onehot: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """all_to_all over the leading dim inside a manual region.

    ``x`` has shape (axis_size, ...): row j is this rank's message for
    rank j. Returns (axis_size, ...): row j is rank j's message for
    this rank.
    """
    if NATIVE_MANUAL_COLLECTIVES and isinstance(axis, str):
        return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    if onehot is None:
        onehot = manual_axis_onehot(axis, axis_size, tie=x)
    gathered = manual_all_gather(x, axis, axis_size, onehot)  # (P, P, ...)
    mask = onehot.reshape((1, axis_size) + (1,) * (x.ndim - 1))
    wide = jnp.float32 if x.dtype == jnp.int8 else x.dtype
    out = jnp.sum(mask * gathered.astype(wide), axis=1)
    return out.astype(x.dtype)
