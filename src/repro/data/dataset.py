"""Dataset API over shard indexes (paper: torch Dataset semantics).

``__len__`` / ``__getitem__`` with lazy per-shard open: the shard
memmaps are opened on first touch *by the consuming thread/process* and
held in a bounded LRU (loader.py) — the paper's "open inside
__getitem__, not __init__" rule that makes multi-worker loading safe.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro.data.shards import ShardIndex


class ShardedDataset:
    def __init__(self, index: ShardIndex, lru_shards: int = 8):
        self.index = index
        self.lru_shards = lru_shards
        self._cache: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0

    def __len__(self) -> int:
        return len(self.index)

    def _shard(self, shard: int, field: str) -> np.ndarray:
        key = (shard, field)
        if key in self._cache:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            return self._cache[key]
        self.cache_misses += 1
        arr = self.index.open_shard(shard, field)      # lazy open
        self._cache[key] = arr
        while len(self._cache) > self.lru_shards * len(self.index.fields):
            self._cache.popitem(last=False)            # LRU eviction
        return arr

    def __getitem__(self, idx: int) -> Dict[str, np.ndarray]:
        shard, off = self.index.locate(int(idx))
        return {f: np.asarray(self._shard(shard, f)[off])
                for f in self.index.fields}

    def gather(self, indices) -> Dict[str, np.ndarray]:
        """Batched fetch: groups indices by shard to touch each shard
        file once (the shard-parallel load path)."""
        indices = np.asarray(indices, np.int64)
        out = {f: np.empty((len(indices),) + tuple(m["shape"]),
                           np.dtype(m["dtype"]))
               for f, m in self.index.fields.items()}
        locs = np.array([self.index.locate(int(i)) for i in indices])
        if len(locs) == 0:
            return out
        for shard in np.unique(locs[:, 0]):
            mask = locs[:, 0] == shard
            offs = locs[mask, 1]
            for f in self.index.fields:
                out[f][mask] = self._shard(int(shard), f)[offs]
        return out

    def sequence_lengths(self, length_field: Optional[str] = None
                         ) -> np.ndarray:
        """Per-record token counts for max-tokens batching. Uses the
        ``length_field`` if present, else the fixed label width."""
        if length_field and length_field in self.index.fields:
            lens = []
            for s in range(self.index.num_shards):
                lens.append(np.asarray(self.index.open_shard(
                    s, length_field)))
            return np.concatenate(lens)
        width = self.index.fields["labels"]["shape"][0]
        return np.full(len(self), width, np.int64)
