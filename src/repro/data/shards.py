"""M5 — self-describing sharded dataset format (memmap + JSON manifest).

The paper uses HDF5/h5py for self-describing, hierarchically-grouped,
multi-tensor shards. h5py is not available in this environment, so the
same design is built on raw ``.npy`` shards:

  <dir>/manifest.json                  dtypes, shapes, per-shard rows
  <dir>/shard_00000.<field>.npy        one file per field per shard

Properties preserved from the paper's design:
  * multiple dependent tensors per record ("fields"), arbitrary dtypes;
  * shards loadable in parallel (each .npy opens independently, memmap);
  * a global index: record i -> (shard, offset) via cumulative lengths
    (the paper's "accumulate the lengths of each file" class);
  * lazy open — files are opened inside ``__getitem__``, never held by
    the constructing process (the paper's fork-safety trick for
    multi-worker loading).
"""
from __future__ import annotations

import bisect
import json
import os
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

MANIFEST = "manifest.json"


def write_shards(out_dir: str, records: Dict[str, np.ndarray],
                 rows_per_shard: int) -> "ShardIndex":
    """Split per-field arrays (same leading dim) into shard files."""
    os.makedirs(out_dir, exist_ok=True)
    fields = sorted(records)
    n = records[fields[0]].shape[0]
    for f in fields:
        if records[f].shape[0] != n:
            raise ValueError("all fields need the same number of rows")
    shards = []
    for si, start in enumerate(range(0, n, rows_per_shard)):
        stop = min(start + rows_per_shard, n)
        for f in fields:
            np.save(os.path.join(out_dir, f"shard_{si:05d}.{f}.npy"),
                    records[f][start:stop])
        shards.append(stop - start)
    manifest = {
        "version": 1,
        "fields": {f: {"dtype": str(records[f].dtype),
                       "shape": list(records[f].shape[1:])}
                   for f in fields},
        "shard_rows": shards,
    }
    with open(os.path.join(out_dir, MANIFEST), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return ShardIndex(out_dir)


class ShardIndex:
    """Global record index over a shard directory (host-side, cheap)."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, MANIFEST)) as fh:
            self.manifest = json.load(fh)
        self.shard_rows: List[int] = self.manifest["shard_rows"]
        self.fields: Dict[str, Dict] = self.manifest["fields"]
        self._cum = np.concatenate([[0], np.cumsum(self.shard_rows)])

    def __len__(self) -> int:
        return int(self._cum[-1])

    @property
    def num_shards(self) -> int:
        return len(self.shard_rows)

    def locate(self, idx: int) -> Tuple[int, int]:
        """global index -> (shard, offset)."""
        if idx < 0 or idx >= len(self):
            raise IndexError(idx)
        s = bisect.bisect_right(self._cum, idx) - 1
        return s, idx - int(self._cum[s])

    def shard_file(self, shard: int, field: str) -> str:
        return os.path.join(self.path, f"shard_{shard:05d}.{field}.npy")

    def open_shard(self, shard: int, field: str) -> np.ndarray:
        """Memmap one shard file (lazy: call inside __getitem__)."""
        return np.load(self.shard_file(shard, field), mmap_mode="r")
