"""Heterogeneity-aware batch sampler (paper: forward-pass sampling).

Responsibilities:
  * deterministic epoch plans: the permutation of record indices derives
    from (seed, epoch) ONLY — never from rank count or capacities — so
    elastic re-meshes and replans reproduce the identical global sample
    stream (paper: reproducible shuffling; our Cython-analogue is a
    precomputed NumPy plan, zero per-step Python in the hot path);
  * max-tokens batching: greedy length-bucketed packing that fills a
    global token budget (paper: "maximize number of tokens in a batch");
  * capacity-aware slicing: each global batch is split across DP ranks
    per the CapacityPlan (rank r takes the next n_r rows), then padded
    into uniform buffers with weight-0 dummies (core/dummy.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.capacity import CapacityPlan
from repro.core.dummy import pack_global_batch, unpack_real_rows
from repro.data.dataset import ShardedDataset


def epoch_permutation(num_records: int, seed: int, epoch: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    return rng.permutation(num_records)


@dataclasses.dataclass(frozen=True)
class BatchPlanEntry:
    indices: np.ndarray            # record ids in this global batch


def plan_epoch_batches(
    num_records: int,
    seed: int,
    epoch: int,
    *,
    global_rows: Optional[int] = None,
    max_tokens: Optional[int] = None,
    lengths: Optional[np.ndarray] = None,
    drop_last: bool = False,
) -> List[BatchPlanEntry]:
    """Either fixed-rows batches or max-tokens batches over one epoch.

    The final batch may be partial — the paper's epoch-boundary case;
    the capacity planner turns the shortfall into dummy rows.
    """
    perm = epoch_permutation(num_records, seed, epoch)
    batches: List[BatchPlanEntry] = []
    if max_tokens is not None:
        if lengths is None:
            raise ValueError("max_tokens batching needs per-record lengths")
        cur: List[int] = []
        cur_tokens = 0
        for idx in perm:
            l = int(lengths[idx])
            if cur and cur_tokens + l > max_tokens:
                batches.append(BatchPlanEntry(np.asarray(cur, np.int64)))
                cur, cur_tokens = [], 0
            cur.append(int(idx))
            cur_tokens += l
        if cur and not drop_last:
            batches.append(BatchPlanEntry(np.asarray(cur, np.int64)))
    else:
        if global_rows is None:
            raise ValueError("need global_rows or max_tokens")
        for start in range(0, num_records, global_rows):
            idx = perm[start:start + global_rows]
            if len(idx) < global_rows and drop_last:
                break
            batches.append(BatchPlanEntry(idx))
    return batches


class HetSampler:
    """Iterates packed SPMD batches for one epoch under a CapacityPlan."""

    def __init__(self, dataset: ShardedDataset, plan: CapacityPlan,
                 seed: int, input_field: str = "inputs",
                 label_field: str = "labels",
                 max_tokens: Optional[int] = None,
                 canonical_order: bool = False):
        self.dataset = dataset
        self.plan = plan
        self.seed = seed
        self.input_field = input_field
        self.label_field = label_field
        self.max_tokens = max_tokens
        self.canonical_order = canonical_order

    def set_plan(self, plan: CapacityPlan) -> None:
        """Capacity replan between steps (straggler feedback)."""
        self.plan = plan

    def epoch_batches(self, epoch: int) -> List[BatchPlanEntry]:
        lengths = (self.dataset.sequence_lengths()
                   if self.max_tokens is not None else None)
        return plan_epoch_batches(
            len(self.dataset), self.seed, epoch,
            global_rows=(None if self.max_tokens else self.plan.global_rows),
            max_tokens=self.max_tokens, lengths=lengths)

    def pack(self, entry: BatchPlanEntry) -> Dict[str, np.ndarray]:
        """Fetch + pack one global batch into the padded SPMD layout.

        Short (epoch-final) batches are padded with dummy rows via a
        shrunken per-batch plan — the paper's partial/empty batch case.
        """
        recs = self.dataset.gather(entry.indices)
        rows = len(entry.indices)
        plan = self.plan
        if rows != plan.global_rows:
            from repro.core.capacity import plan_capacities
            plan = plan_capacities(rows, plan.capacities,
                                   buffer_rows=plan.buffer_rows)
        samples = {"inputs": recs[self.input_field],
                   "labels": recs[self.label_field]}
        weights = recs.get("weights")
        packed = pack_global_batch(samples, plan, token_weights=weights)
        if not self.canonical_order:
            return packed
        # canonical mode (weighting="canonical"): rows in global-row
        # order, NOT rank-buffer order — the order-canonical train step
        # sums per-row grads along this axis with one fixed tree, so
        # the layout must not depend on the plan. Partial batches pad
        # with weight-0 rows at the END (a trailing zero term keeps the
        # reduction tree of the real rows intact; an interleaved one
        # would regroup it), keeping the batch shape static at
        # global_rows.
        real = unpack_real_rows(packed, plan)
        rows = real["inputs"].shape[0]
        target = self.plan.global_rows
        if rows < target:
            pad = target - rows
            real = {
                k: np.concatenate(
                    [v, np.repeat(v[:1], pad, axis=0)], axis=0)
                for k, v in real.items()}
            real["weights"][rows:] = 0.0
        return real

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iter_epoch(0)

    def iter_epoch(self, epoch: int) -> Iterator[Dict[str, np.ndarray]]:
        for entry in self.epoch_batches(epoch):
            yield self.pack(entry)
