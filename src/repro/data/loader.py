"""M6 — prefetching dataloader with LRU shard cache.

The paper: "with prefetch we fetch the next batch while training on the
current batch; LRU caching stores shards in memory." Here a background
thread runs the sampler's fetch+pack (pure NumPy) into a bounded queue
while the main thread feeds the device; the ShardedDataset's LRU keeps
hot shard memmaps open.

``depth`` > 1 prefetches multiple batches when host memory allows
(paper: "when memory capacity allows we can prefetch multiple batches").
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.sampler import HetSampler

_SENTINEL = object()


class PrefetchLoader:
    def __init__(self, sampler: HetSampler, depth: int = 2):
        self.sampler = sampler
        self.depth = max(1, depth)

    def iter_epoch(self, epoch: int) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        err: list = []

        def producer():
            try:
                for batch in self.sampler.iter_epoch(epoch):
                    q.put(batch)
            except BaseException as e:          # surface in consumer
                err.append(e)
            finally:
                q.put(_SENTINEL)

        t = threading.Thread(target=producer, daemon=True,
                             name=f"prefetch-epoch{epoch}")
        t.start()
        try:
            while True:
                item = q.get()
                if item is _SENTINEL:
                    break
                yield item
            if err:
                raise err[0]
        finally:
            t.join(timeout=5.0)

    def cache_stats(self) -> Dict[str, int]:
        ds = self.sampler.dataset
        return {"hits": ds.cache_hits, "misses": ds.cache_misses}
