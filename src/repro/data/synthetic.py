"""Deterministic synthetic corpora for tests, benchmarks and examples.

Zipfian token streams (text-like marginal statistics) with a learnable
bigram structure so small models show decreasing loss; generation is
pure (seed -> bytes), so any two hosts materialize identical shards —
required for the elastic-restart equivalence tests.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from repro.data.shards import ShardIndex, write_shards


def zipf_bigram_tokens(num_seqs: int, seq_len: int, vocab: int,
                       seed: int = 0) -> np.ndarray:
    """(num_seqs, seq_len + 1) int32: zipf unigrams + deterministic
    bigram transitions (token -> (a * token + c) % vocab with noise)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = np.empty((num_seqs, seq_len + 1), np.int32)
    toks[:, 0] = rng.choice(vocab, size=num_seqs, p=probs)
    a, c = 31, 17
    for t in range(1, seq_len + 1):
        follow = (a * toks[:, t - 1] + c) % vocab
        noise = rng.choice(vocab, size=num_seqs, p=probs)
        use_bigram = rng.random(num_seqs) < 0.7
        toks[:, t] = np.where(use_bigram, follow, noise)
    return toks


def make_lm_records(num_seqs: int, seq_len: int, vocab: int,
                    seed: int = 0, varlen: bool = False
                    ) -> Dict[str, np.ndarray]:
    """inputs/labels (shifted), optional ragged lengths + pad weights."""
    toks = zipf_bigram_tokens(num_seqs, seq_len, vocab, seed)
    rec = {"inputs": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if varlen:
        rng = np.random.default_rng(seed + 1)
        lens = rng.integers(seq_len // 4, seq_len + 1, size=num_seqs)
        w = (np.arange(seq_len)[None, :] < lens[:, None]).astype(np.float32)
        rec["weights"] = w
        rec["lengths"] = lens.astype(np.int64)
    return rec


def build_synthetic_corpus(out_dir: str, num_seqs: int = 512,
                           seq_len: int = 128, vocab: int = 256,
                           rows_per_shard: int = 64, seed: int = 0,
                           varlen: bool = False) -> ShardIndex:
    if os.path.exists(os.path.join(out_dir, "manifest.json")):
        return ShardIndex(out_dir)
    rec = make_lm_records(num_seqs, seq_len, vocab, seed, varlen)
    return write_shards(out_dir, rec, rows_per_shard)
