"""Free-list allocation over the paged KV pool.

The pool itself is a device array (models/kvcache.py); this module is
the host-side accountant that decides which physical blocks a sequence
owns. Blocks are partitioned across pods with the same balanced-extent
math the checkpoint writer uses to shard bucket rows across hosts
(core/buckets.py::host_shard_extents): pod p allocates only from its
contiguous [lo, hi) extent, so a sequence's cache blocks are co-located
with the pod that decodes it.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core import buckets as bkt
from repro.models.kvcache import PagedLayout


class BlockPool:
    """LIFO free-list over a contiguous range of physical block ids."""

    def __init__(self, layout: PagedLayout,
                 extent: Tuple[int, int] = None):
        lo, hi = extent if extent is not None else (0, layout.num_blocks)
        if not (0 <= lo <= hi <= layout.num_blocks):
            raise ValueError(
                f"extent {(lo, hi)} outside pool of {layout.num_blocks} "
                f"blocks")
        self.layout = layout
        self.extent = (lo, hi)
        self._free: List[int] = list(range(hi - 1, lo - 1, -1))
        self._allocated = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_blocks(self) -> int:
        return self.extent[1] - self.extent[0]

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"pool extent {self.extent}: need {n} blocks, "
                f"{len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise RuntimeError(f"double free of block {b}")
            self._allocated.discard(b)
            self._free.append(b)


def pod_block_pools(layout: PagedLayout, pods: int) -> List[BlockPool]:
    """Partition the pool into one balanced contiguous extent per pod."""
    return [BlockPool(layout, extent)
            for extent in bkt.host_shard_extents(layout.num_blocks, pods)]
