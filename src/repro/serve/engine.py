"""The continuous-batching decode loop.

Each iteration: ingest due arrivals, admit what fits (scheduler),
prefill the admitted prompts in length buckets, then run ONE decode
step for the whole slot batch — every active sequence advances one
token at its own depth (per-sequence ``kv_lens``), finished sequences
free their blocks immediately and their slots are refilled next
iteration. The decode step is compiled exactly once: fixed shapes
(D,), (D, MB), (D,); inactive slots carry kv_len=0 and all-NULL block
tables, so their writes drop and their outputs are discarded host-side.
The engine asserts the step never retraced at the end of a run.

**Modeled clock.** Real wall time on the host container measures the
emulated mesh, not the heterogeneous fleet, so throughput/latency stats
ride on a deterministic cost model in abstract time units, consistent
with the trainer's capacity math (one unit == one decode-token on a
speed-1.0 pod):

- decode iteration:  dt = max_p active_p / speed_p
- prefill of a bucket-L group: dt = max_p rows_p * L / speed_p

Both are max-over-pods because the mesh is one SPMD program — the step
returns when the slowest pod finishes, which is exactly why the router
gives slow pods proportionally fewer sequences (min-max of
active_p/speed_p is the HetSeq capacity argument on the serving side).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.models.kvcache import PagedLayout
from repro.serve.scheduler import Request, Scheduler, SeqState


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    decode_slots: int
    prefill_batch: int
    max_iterations: int = 100_000     # runaway-loop guard, fail loud
    # which decode attention ran (ModelConfig.attention_impl at build
    # time) — recorded in stats so a serving run is auditable about
    # whether the hot path used the in-kernel block gather
    attention_impl: str = "reference"


@dataclasses.dataclass
class ServeResult:
    tokens: Dict[int, List[int]]      # rid -> generated token ids
    stats: Dict[str, Any]


class ServeEngine:
    """Ties scheduler + jitted paged steps into a serving loop.

    ``decode_fn(params, tokens, cache, tables, kv_lens)`` and
    ``prefill_fns[bucket](params, prompts, lens, cache, tables)`` come
    from launch/steps.py (donated caches); ``init_cache_fn()`` builds
    the zeroed pool with the right shardings.
    """

    def __init__(self, cfg: EngineConfig, layout: PagedLayout,
                 scheduler: Scheduler,
                 decode_fn: Callable,
                 prefill_fns: Dict[int, Callable],
                 init_cache_fn: Callable[[], Any]):
        missing = [b for b in scheduler.bucket_lens
                   if b not in prefill_fns]
        if missing:
            raise ValueError(f"no prefill step for buckets {missing}")
        self.cfg = cfg
        self.layout = layout
        self.sched = scheduler
        self.decode_fn = decode_fn
        self.prefill_fns = prefill_fns
        self.init_cache_fn = init_cache_fn

    # -- modeled costs -----------------------------------------------------

    def _decode_dt(self) -> float:
        speeds = self.sched.router.pod_speeds
        return max((a / speeds[p]
                    for p, a in enumerate(self.sched.active_per_pod)
                    if a > 0), default=0.0)

    def _prefill_dt(self, bucket: int, seqs: Sequence[SeqState]) -> float:
        speeds = self.sched.router.pod_speeds
        rows = [0] * len(speeds)
        for s in seqs:
            rows[s.pod] += 1
        return max((r * bucket / speeds[p]
                    for p, r in enumerate(rows) if r > 0), default=0.0)

    # -- the loop ----------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeResult:
        sched, layout = self.sched, self.layout
        NULL = layout.null_block
        D, MB = self.cfg.decode_slots, layout.max_blocks_per_seq

        arrivals = sorted(requests, key=lambda r: (r.arrival, r.rid))
        tokens_out: Dict[int, List[int]] = {r.rid: [] for r in arrivals}
        token_times: Dict[int, List[float]] = {r.rid: [] for r in arrivals}
        arrival_of = {r.rid: r.arrival for r in arrivals}

        cache = self.init_cache_fn()
        clock, ai = 0.0, 0
        decode_steps = prefill_groups = 0
        peak_active = [0] * sched.router.num_pods
        block_util_peak, block_util_sum, util_samples = 0.0, 0.0, 0
        wall0 = time.monotonic()

        def emit(seq: SeqState, tok: int, t: float) -> None:
            seq.generated.append(tok)
            seq.last_token = tok
            tokens_out[seq.rid].append(tok)
            token_times[seq.rid].append(t)
            if seq.done:
                sched.finish(seq)

        it = 0
        while ai < len(arrivals) or sched.waiting or sched.running:
            it += 1
            if it > self.cfg.max_iterations:
                raise RuntimeError(
                    f"serve loop exceeded {self.cfg.max_iterations} "
                    f"iterations — scheduler stuck?")
            # idle: jump the clock to the next arrival
            if (not sched.running and not sched.waiting
                    and ai < len(arrivals)):
                clock = max(clock, arrivals[ai].arrival)
            while ai < len(arrivals) and arrivals[ai].arrival <= clock:
                sched.submit(arrivals[ai])
                ai += 1

            admitted = sched.try_admit()
            by_bucket: Dict[int, List[SeqState]] = {}
            for seq in admitted:
                by_bucket.setdefault(
                    sched.bucket_for(len(seq.prompt)), []).append(seq)
            for bucket in sorted(by_bucket):
                group = by_bucket[bucket]
                Bp = self.cfg.prefill_batch
                for lo in range(0, len(group), Bp):
                    chunk = group[lo:lo + Bp]
                    cache, logits = self._prefill(chunk, bucket, Bp,
                                                  cache, NULL, MB)
                    clock += self._prefill_dt(bucket, chunk)
                    prefill_groups += 1
                    toks = np.argmax(logits[:len(chunk)], axis=-1)
                    for seq, tok in zip(chunk, toks):
                        seq.kv_len = len(seq.prompt)
                        emit(seq, int(tok), clock)

            if sched.running:
                # grow block tables BEFORE the step (the new token
                # writes at position kv_len); may preempt newest-first
                for slot in sorted(sched.running):
                    seq = sched.running.get(slot)
                    if seq is not None and not sched.ensure_next_block(
                            seq):
                        continue            # seq preempted itself
                if not sched.running:
                    continue
                tok_arr = np.zeros((D,), np.int32)
                tbl_arr = np.full((D, MB), NULL, np.int32)
                len_arr = np.zeros((D,), np.int32)
                for slot, seq in sched.running.items():
                    tok_arr[slot] = seq.last_token
                    tbl_arr[slot, :len(seq.blocks)] = seq.blocks
                    len_arr[slot] = seq.kv_len
                logits, cache = self.decode_fn(
                    jnp.asarray(tok_arr), cache, jnp.asarray(tbl_arr),
                    jnp.asarray(len_arr))
                clock += self._decode_dt()
                decode_steps += 1
                for p, a in enumerate(sched.active_per_pod):
                    peak_active[p] = max(peak_active[p], a)
                util = sched.allocated_blocks() / layout.num_blocks
                block_util_peak = max(block_util_peak, util)
                block_util_sum += util
                util_samples += 1
                logits_h = np.asarray(logits)
                for slot, seq in list(sched.running.items()):
                    seq.kv_len += 1
                    emit(seq, int(np.argmax(logits_h[slot])), clock)

        wall = time.monotonic() - wall0
        self._assert_no_retrace()
        total_tokens = sum(len(v) for v in tokens_out.values())
        tpot = [(token_times[rid][-1] - arrival_of[rid]) / len(ts)
                for rid, ts in token_times.items() if ts]
        ttft = [ts[0] - arrival_of[rid]
                for rid, ts in token_times.items() if ts]
        stats = {
            "requests": len(arrivals),
            "total_tokens": total_tokens,
            "modeled_time": clock,
            "modeled_tokens_per_sec": (total_tokens / clock
                                       if clock > 0 else 0.0),
            "p50_time_per_token": (float(np.percentile(tpot, 50))
                                   if tpot else 0.0),
            "p99_time_per_token": (float(np.percentile(tpot, 99))
                                   if tpot else 0.0),
            "mean_ttft": float(np.mean(ttft)) if ttft else 0.0,
            "decode_steps": decode_steps,
            "prefill_groups": prefill_groups,
            "preemptions": sched.preemptions,
            "peak_active_per_pod": [int(x) for x in peak_active],
            "pod_limits": [int(x) for x in sched.router.limits],
            "block_util_peak": block_util_peak,
            "block_util_mean": (block_util_sum / util_samples
                                if util_samples else 0.0),
            "attention_impl": self.cfg.attention_impl,
            "wall_seconds": wall,
        }
        return ServeResult(tokens=tokens_out, stats=stats)

    def _prefill(self, chunk: Sequence[SeqState], bucket: int, Bp: int,
                 cache: Any, NULL: int, MB: int):
        prompts = np.zeros((Bp, bucket), np.int32)
        lens = np.zeros((Bp,), np.int32)
        tables = np.full((Bp, MB), NULL, np.int32)
        for i, seq in enumerate(chunk):
            prompts[i, :len(seq.prompt)] = seq.prompt
            lens[i] = len(seq.prompt)
            tables[i, :len(seq.blocks)] = seq.blocks
        logits, cache = self.prefill_fns[bucket](
            jnp.asarray(prompts), jnp.asarray(lens), cache,
            jnp.asarray(tables))
        return cache, np.asarray(logits)

    def _assert_no_retrace(self) -> None:
        """Fail loud if the decode step compiled more than once — a
        retrace means some input shape/dtype varied across iterations
        and the whole fixed-shape design is broken."""
        n = _trace_count(self.decode_fn)
        if n is not None and n > 1:
            raise RuntimeError(
                f"paged decode step retraced: {n} compilations for one "
                f"engine run (expected 1)")


def _trace_count(fn) -> Optional[int]:
    target = getattr(fn, "func", fn)        # unwrap functools.partial
    size = getattr(target, "_cache_size", None)
    return size() if callable(size) else None
