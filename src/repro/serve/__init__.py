"""Continuous-batching serving engine on the heterogeneous mesh.

- blocks.py    — paged-pool free-list allocator, per-pod extents
- router.py    — capacity-aware request routing (CapacityPlan limits)
- scheduler.py — admission / preemption / length-bucketed prefill
- engine.py    — the decode loop tying it all together

See docs/architecture.md §serving engine.
"""
from repro.serve.blocks import BlockPool, pod_block_pools
from repro.serve.engine import EngineConfig, ServeEngine, ServeResult
from repro.serve.router import CapacityRouter
from repro.serve.scheduler import Request, Scheduler, SeqState

__all__ = ["BlockPool", "pod_block_pools", "CapacityRouter", "Request",
           "Scheduler", "SeqState", "EngineConfig", "ServeEngine",
           "ServeResult"]
