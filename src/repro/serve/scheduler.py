"""Admission, preemption, and prefill bucketing for continuous batching.

The scheduler is pure host-side bookkeeping over three budgets:

- **decode slots** — the fixed batch width D of the jitted decode step;
  a free slot is a row in that batch.
- **per-pod concurrency** — the CapacityRouter's ``rows_per_rank``
  limits: a slow pod holds proportionally fewer concurrent sequences.
- **blocks** — each pod's extent of the paged pool (serve/blocks.py);
  a sequence needs ceil(len / block_size) blocks at admission and one
  more each time its kv_len crosses a block boundary.

Admission is strict FIFO (head-of-line blocking keeps the trace
deterministic and starvation-free). When a running sequence cannot get
its next block, the *newest* running sequence on the same pod is
preempted: its blocks are freed and it re-enters the FRONT of the
waiting queue as a longer prompt (original prompt + tokens generated so
far), to be re-prefilled later. The oldest running sequence is never
the victim while others exist, so the system always drains.

Prompts are prefilled in length buckets — multiples of the block size —
so the engine compiles one prefill program per bucket instead of one
per prompt length.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.models.kvcache import PagedLayout
from repro.serve.blocks import BlockPool, pod_block_pools
from repro.serve.router import CapacityRouter


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is the token ids; after a
    preemption the re-queued request carries prompt + generated-so-far
    and the remaining token budget."""
    rid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival: float = 0.0


@dataclasses.dataclass
class SeqState:
    """A running sequence: its decode-batch slot, pod, owned blocks,
    and current cache depth."""
    rid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int          # remaining budget for THIS admission
    arrival: float
    pod: int
    slot: int
    blocks: List[int]
    kv_len: int = 0              # tokens currently in the paged cache
    last_token: int = -1         # input to the next decode step
    generated: List[int] = dataclasses.field(default_factory=list)
    admit_order: int = 0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Scheduler:
    def __init__(self, layout: PagedLayout, router: CapacityRouter,
                 decode_slots: int,
                 bucket_lens: Optional[Sequence[int]] = None):
        self.layout = layout
        self.router = router
        self.decode_slots = decode_slots
        self.pools: List[BlockPool] = pod_block_pools(layout,
                                                      router.num_pods)
        if bucket_lens is None:
            bucket_lens = default_bucket_lens(layout)
        self.bucket_lens = tuple(sorted(set(int(b) for b in bucket_lens)))
        for b in self.bucket_lens:
            if b <= 0 or b % layout.block_size:
                raise ValueError(
                    f"prefill bucket {b} is not a positive multiple of "
                    f"block size {layout.block_size}")
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, SeqState] = {}      # slot -> seq
        self._free_slots = list(range(decode_slots - 1, -1, -1))
        self.active_per_pod = [0] * router.num_pods
        self._admit_counter = 0
        self.preemptions = 0

    # -- budgets -----------------------------------------------------------

    def bucket_for(self, length: int) -> int:
        for b in self.bucket_lens:
            if b >= length:
                return b
        raise ValueError(
            f"prompt of {length} tokens exceeds the largest prefill "
            f"bucket {self.bucket_lens[-1]}")

    def allocated_blocks(self) -> int:
        return sum(len(s.blocks) for s in self.running.values())

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens <= 0:
            raise ValueError(f"request {req.rid}: max_new_tokens must "
                             f"be positive")
        if total > self.layout.max_seq_len:
            raise ValueError(
                f"request {req.rid}: {total} tokens exceeds layout max "
                f"{self.layout.max_seq_len}")
        need = self.layout.blocks_for(total)
        fits = max((p.num_blocks for p, lim in zip(self.pools,
                                                   self.router.limits)
                    if lim > 0), default=0)
        if need > fits:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks but the largest "
                f"admitting pod extent holds {fits}")
        self.bucket_for(len(req.prompt))   # raises if no bucket fits
        self.waiting.append(req)

    def try_admit(self) -> List[SeqState]:
        """Admit waiting requests FIFO while slots / pod limits / blocks
        allow. Returns the newly admitted sequences (to be prefilled)."""
        admitted: List[SeqState] = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need = self.layout.blocks_for(len(req.prompt))
            pod = self._route_with_blocks(need)
            if pod is None:
                break                       # head-of-line blocks: FIFO
            self.waiting.popleft()
            seq = SeqState(
                rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, arrival=req.arrival,
                pod=pod, slot=self._free_slots.pop(),
                blocks=self.pools[pod].alloc(need),
                admit_order=self._admit_counter)
            self._admit_counter += 1
            self.running[seq.slot] = seq
            self.active_per_pod[pod] += 1
            admitted.append(seq)
        return admitted

    def _route_with_blocks(self, need: int) -> Optional[int]:
        """Route respecting pod limits AND that pod's block extent."""
        active = list(self.active_per_pod)
        while True:
            pod = self.router.route(active)
            if pod is None:
                return None
            if self.pools[pod].num_free >= need:
                return pod
            active[pod] = self.router.limits[pod]   # mask it, try next

    def ensure_next_block(self, seq: SeqState) -> bool:
        """Guarantee the block holding position ``kv_len`` exists before
        a decode step writes there. May preempt (newest-first, same
        pod); returns False if ``seq`` itself got preempted."""
        needed = seq.kv_len // self.layout.block_size
        if needed < len(seq.blocks):
            return True
        pool = self.pools[seq.pod]
        while pool.num_free < 1:
            victim = self._newest_on_pod(seq.pod)
            self.preempt(victim)
            if victim is seq:
                return False
        seq.blocks.extend(pool.alloc(1))
        return True

    def _newest_on_pod(self, pod: int) -> SeqState:
        cands = [s for s in self.running.values() if s.pod == pod]
        return max(cands, key=lambda s: s.admit_order)

    def preempt(self, seq: SeqState) -> None:
        """Evict: free blocks + slot, re-queue at the FRONT as a longer
        prompt with the remaining token budget."""
        self._release(seq)
        self.preemptions += 1
        self.waiting.appendleft(Request(
            rid=seq.rid,
            prompt=seq.prompt + tuple(seq.generated),
            max_new_tokens=seq.max_new_tokens - len(seq.generated),
            arrival=seq.arrival))

    def finish(self, seq: SeqState) -> None:
        self._release(seq)

    def _release(self, seq: SeqState) -> None:
        del self.running[seq.slot]
        self.pools[seq.pod].free(seq.blocks)
        self.active_per_pod[seq.pod] -= 1
        self._free_slots.append(seq.slot)


def default_bucket_lens(layout: PagedLayout) -> Tuple[int, ...]:
    """Power-of-two multiples of the block size up to the layout max."""
    out, b = [], layout.block_size
    while b < layout.max_seq_len:
        out.append(b)
        b *= 2
    out.append(layout.max_seq_len)
    return tuple(out)
