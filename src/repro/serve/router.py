"""Capacity-aware request routing across heterogeneous pods.

HetSeq's training-side answer to heterogeneity is a CapacityPlan: rows
per rank proportional to measured speed. Serving reuses the exact same
planner as an *admission weight table* — ``plan_capacities(decode_slots,
pod_speeds)`` yields per-pod concurrency limits summing to the decode
batch, so a pod at half speed holds half the concurrent sequences and
the modeled per-iteration decode time max_p(active_p / speed_p) stays
balanced (Poplar's throughput-proportional load assignment, PAPERS.md).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.core import capacity


class CapacityRouter:
    """Assign each request a pod, bounded by CapacityPlan row limits."""

    def __init__(self, decode_slots: int, pod_speeds: Sequence[float]):
        if decode_slots <= 0:
            raise ValueError(f"decode_slots must be positive, got "
                             f"{decode_slots}")
        self.pod_speeds = tuple(float(s) for s in pod_speeds)
        self.plan = capacity.plan_capacities(decode_slots,
                                             self.pod_speeds)
        if sum(self.plan.rows_per_rank) == 0:
            raise ValueError(
                f"pod speeds {self.pod_speeds} plan to zero concurrency")

    @property
    def num_pods(self) -> int:
        return self.plan.num_ranks

    @property
    def limits(self) -> Tuple[int, ...]:
        """Max concurrent sequences per pod (rows ∝ capacity score)."""
        return self.plan.rows_per_rank

    def route(self, active_per_pod: Sequence[int]) -> Optional[int]:
        """Pick the pod with the most free weighted headroom.

        Returns None when every pod is at its limit. Headroom is
        normalized by the limit so a 2-slot slow pod at 1 active is as
        "full" as an 8-slot fast pod at 4 — absolute headroom would
        funnel every burst to the fast pod and idle the slow one.
        """
        best, best_key = None, None
        for p, (limit, active) in enumerate(zip(self.limits,
                                                active_per_pod)):
            if active >= limit or limit == 0:
                continue
            key = ((limit - active) / limit, self.pod_speeds[p])
            if best_key is None or key > best_key:
                best, best_key = p, key
        return best
