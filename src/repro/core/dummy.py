"""M3 — dummy-batch construction and weight masks.

The paper: when a GPU's batch is empty at an epoch boundary, it runs a
*dummy batch* (a copy of its first real batch) whose gradient is zeroed,
so NCCL collectives still fire. Partially-filled batches carry their true
sample count as the aggregation weight.

Here every DP rank owns a fixed-size buffer (capacity.py); this module
fills buffers: real rows first, then dummy rows that *copy row 0 of the
global batch* (numerically safe — real token ids, finite activations)
with per-token weight 0. The weighted aggregation (weighting.py) then
makes dummy rows exact no-ops in the loss and gradient.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.capacity import CapacityPlan


def pack_global_batch(
    samples: Dict[str, np.ndarray],
    plan: CapacityPlan,
    token_weights: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Distribute ``global_rows`` samples into the padded (R * buffer)
    layout the SPMD step consumes.

    samples: {"inputs": (G, S[, d]), "labels": (G, S)}; rows 0..G-1 are
    assigned to ranks in plan order (rank r gets the next n_r rows).
    Returns {"inputs", "labels", "weights"} with leading dim
    R * buffer_rows — shard this over the DP axes.

    ``token_weights`` (G, S) marks real-token weights within real rows
    (e.g. 0 for padding tokens inside a sequence); defaults to all-ones.
    """
    g = samples["labels"].shape[0]
    if g != plan.global_rows:
        raise ValueError(f"got {g} rows, plan expects {plan.global_rows}")
    seq_shape = samples["labels"].shape[1:]
    if token_weights is None:
        token_weights = np.ones((g,) + seq_shape, np.float32)

    out_rows = plan.padded_rows
    packed: Dict[str, np.ndarray] = {}
    for key in ("inputs", "labels"):
        src = samples[key]
        dst = np.empty((out_rows,) + src.shape[1:], src.dtype)
        # dummy rows copy row 0 (the paper's "copy its very first batch")
        dst[:] = src[0]
        cursor = 0
        for r, n in enumerate(plan.rows_per_rank):
            o = r * plan.buffer_rows
            dst[o:o + n] = src[cursor:cursor + n]
            cursor += n
        packed[key] = dst

    w = np.zeros((out_rows,) + seq_shape, np.float32)
    cursor = 0
    for r, n in enumerate(plan.rows_per_rank):
        o = r * plan.buffer_rows
        w[o:o + n] = token_weights[cursor:cursor + n]
        cursor += n
    packed["weights"] = w
    return packed


def unpack_real_rows(packed: Dict[str, np.ndarray],
                     plan: CapacityPlan) -> Dict[str, np.ndarray]:
    """Inverse of pack_global_batch (test helper): recover the G real
    rows in original order."""
    out: Dict[str, np.ndarray] = {}
    idx = []
    for r, n in enumerate(plan.rows_per_rank):
        o = r * plan.buffer_rows
        idx.extend(range(o, o + n))
    for key in ("inputs", "labels", "weights"):
        out[key] = packed[key][idx]
    return out
