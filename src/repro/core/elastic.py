"""Elastic scaling: re-mesh on membership change, exact-resume semantics.

Two regimes, in escalation order:

1. **Soft degradation (no restart)** — a rank dies mid-window: the
   straggler monitor marks it dead, the capacity planner assigns it 0
   rows (all-dummy buffer, weight 0). SPMD shapes are unchanged, the
   dead rank's host is expected to keep participating in collectives
   (TPU slices fail whole-slice in practice, which is regime 2); for the
   multi-pod DCN case a lost *pod* is regime 2.

2. **Re-mesh restart** — membership changed durably (pod lost/added):
   reload the latest checkpoint, rebuild the mesh with the new DP width,
   and re-plan capacities. Because data order derives from
   (seed, epoch, global_step) — never from rank count — and aggregation
   divides by summed weight, the *global* sample stream and the loss
   are identical across any re-mesh: training resumes exactly.

This module computes the re-mesh decision + new configuration; the
driver (launch/train.py) performs reload/rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.capacity import CapacityPlan, plan_capacities


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Logical description of the available hardware."""

    pods: int
    data_per_pod: int
    model: int

    @property
    def dp_size(self) -> int:
        return self.pods * self.data_per_pod

    def mesh_shape(self) -> Tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.data_per_pod, self.model)
        return (self.data_per_pod, self.model)

    def mesh_axes(self) -> Tuple[str, ...]:
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")


@dataclasses.dataclass(frozen=True)
class RemeshDecision:
    restart_required: bool
    topology: MeshTopology
    plan: CapacityPlan
    reason: str


def plan_remesh(
    current: MeshTopology,
    alive_pods: Sequence[int],
    global_rows: int,
    capacities_per_pod: Optional[Sequence[float]] = None,
) -> RemeshDecision:
    """Decide how to continue after a membership change.

    ``alive_pods``: indices of pods still healthy. If all pods are alive
    this is a no-op (soft path handles intra-pod stragglers). Otherwise
    rebuild with the surviving pods and re-plan the same global batch
    over the smaller DP width — per-rank buffers grow, weights stay
    exact, the optimizer trajectory is unchanged.
    """
    alive = sorted(set(alive_pods))
    if len(alive) == current.pods:
        plan = plan_capacities(
            global_rows,
            np.repeat(np.asarray(capacities_per_pod, np.float64),
                      current.data_per_pod)
            if capacities_per_pod is not None
            else np.ones(current.dp_size))
        return RemeshDecision(False, current, plan, "membership unchanged")
    if not alive:
        raise ValueError("no pods alive")
    new_topo = MeshTopology(pods=len(alive),
                            data_per_pod=current.data_per_pod,
                            model=current.model)
    caps = (np.asarray([capacities_per_pod[p] for p in alive], np.float64)
            if capacities_per_pod is not None else np.ones(len(alive)))
    plan = plan_capacities(global_rows,
                           np.repeat(caps, new_topo.data_per_pod))
    return RemeshDecision(
        True, new_topo, plan,
        f"pods {sorted(set(range(current.pods)) - set(alive))} lost; "
        f"re-mesh to {new_topo.mesh_shape()} and resume from checkpoint")


def validate_resume_equivalence(plan_a: CapacityPlan, plan_b: CapacityPlan
                                ) -> bool:
    """Two plans consume the same global batch (exact-resume invariant)."""
    return plan_a.global_rows == plan_b.global_rows
