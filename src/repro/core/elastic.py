"""Elastic scaling: re-mesh on membership change, exact-resume semantics.

Two regimes, in escalation order:

1. **Soft degradation (no restart)** — a rank dies mid-window: the
   straggler monitor marks it dead, the capacity planner assigns it 0
   rows (all-dummy buffer, weight 0). SPMD shapes are unchanged, the
   dead rank's host is expected to keep participating in collectives
   (TPU slices fail whole-slice in practice, which is regime 2); for the
   multi-pod DCN case a lost *pod* is regime 2.

2. **Re-mesh restart** — membership changed durably (pod lost/added):
   reload the latest checkpoint, rebuild the mesh with the new DP width,
   and re-plan capacities. Because data order derives from
   (seed, epoch, global_step) — never from rank count — and aggregation
   divides by summed weight, the *global* sample stream and the loss
   are identical across any re-mesh: training resumes exactly. The
   checkpoint side holds up its end: v3 saves are per-host shard files
   behind a checksummed manifest (node loss is the common case, so a
   half-written or bit-rotted step is *rejected* and restore falls back
   to the previous committed one), packed optimizer state repacks into
   the new mesh's bucket grid, and the summed int8 error-feedback
   residual is distributed over the new ranks' stream extents — sum
   conserved, no rank restarts carrying the whole fleet's residual
   (checkpoint/checkpoint.py, checkpoint/repack.py).

This module computes the re-mesh decision + new configuration; the
driver (launch/train.py) performs reload/rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.capacity import CapacityPlan, plan_capacities


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Logical description of the available hardware."""

    pods: int
    data_per_pod: int
    model: int

    @property
    def dp_size(self) -> int:
        return self.pods * self.data_per_pod

    def mesh_shape(self) -> Tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.data_per_pod, self.model)
        return (self.data_per_pod, self.model)

    def mesh_axes(self) -> Tuple[str, ...]:
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")


@dataclasses.dataclass(frozen=True)
class RemeshDecision:
    restart_required: bool
    topology: MeshTopology
    plan: CapacityPlan
    reason: str
    # Multiply HetConfig.accum_steps by this on restart to preserve the
    # per-microbatch grid across the DP-width change: the grad the new
    # mesh accumulates then sums the SAME per-microbatch partials in the
    # SAME association order the old mesh's cross-rank psum used, so the
    # resumed trajectory is bit-identical to the uninterrupted run (not
    # just mathematically equal — fp summation grouping is preserved).
    # 1 when the old DP width does not divide evenly (equality then
    # holds to fp reduction-order tolerance only).
    accum_scale: int = 1


def plan_remesh(
    current: MeshTopology,
    alive_pods: Sequence[int],
    global_rows: int,
    capacities_per_pod: Optional[Sequence[float]] = None,
    round_buffer_to: int = 1,
) -> RemeshDecision:
    """Decide how to continue after a membership change.

    ``alive_pods``: indices of pods still healthy. If all pods are alive
    this is a no-op (soft path handles intra-pod stragglers). Otherwise
    rebuild with the surviving pods and re-plan the same global batch
    over the smaller DP width — per-rank buffers grow, weights stay
    exact, the optimizer trajectory is unchanged. ``round_buffer_to``
    (pass the CURRENT accum_steps) keeps the new buffer divisible into
    microbatches: the returned plan's buffer divides by
    ``round_buffer_to * accum_scale``, matching the post-scale
    accum_steps the caller applies on restart.
    """
    alive = sorted(set(alive_pods))
    if len(alive) == current.pods:
        plan = plan_capacities(
            global_rows,
            np.repeat(np.asarray(capacities_per_pod, np.float64),
                      current.data_per_pod)
            if capacities_per_pod is not None
            else np.ones(current.dp_size),
            round_buffer_to=round_buffer_to)
        return RemeshDecision(False, current, plan, "membership unchanged")
    if not alive:
        raise ValueError("no pods alive")
    new_topo = MeshTopology(pods=len(alive),
                            data_per_pod=current.data_per_pod,
                            model=current.model)
    caps = (np.asarray([capacities_per_pod[p] for p in alive], np.float64)
            if capacities_per_pod is not None else np.ones(len(alive)))
    accum_scale = (current.dp_size // new_topo.dp_size
                   if current.dp_size % new_topo.dp_size == 0 else 1)
    # the caller multiplies accum_steps by accum_scale on restart, so
    # the buffer must divide by the PRODUCT (a max() would leave e.g.
    # accum 2 x scale 2 = 4 microbatches over a buffer rounded to 2)
    plan = plan_capacities(global_rows,
                           np.repeat(caps, new_topo.data_per_pod),
                           round_buffer_to=(max(round_buffer_to, 1) *
                                            accum_scale))
    return RemeshDecision(
        True, new_topo, plan,
        f"pods {sorted(set(range(current.pods)) - set(alive))} lost; "
        f"re-mesh to {new_topo.mesh_shape()} and resume from checkpoint",
        accum_scale=accum_scale)


def validate_resume_equivalence(plan_a: CapacityPlan, plan_b: CapacityPlan
                                ) -> bool:
    """Two plans consume the same global record stream (exact resume).

    Comparing ``global_rows`` alone passes plans that consume
    *different* record streams: the sampler hands rank *r* the rows
    ``[sum(n_<r), sum(n_<=r))`` of each global batch, so the invariant
    is about the consumed-row assignment — each plan's
    capacity-normalized per-rank rows must sum to (partition) the same
    global prefix ``[0, global_rows)``, with every rank's slice
    actually fitting its buffer. A plan whose rows over- or under-cover
    the prefix (negative rows, rows past the buffer, sum != global)
    would silently drop or duplicate records on resume. Rank COUNT may
    differ — that is the elastic point; coverage may not.
    """
    def covers_prefix(plan: CapacityPlan) -> bool:
        rows = np.asarray(plan.rows_per_rank, np.int64)
        return (rows.size > 0
                and int(rows.min()) >= 0
                and int(rows.max()) <= plan.buffer_rows
                and int(rows.sum()) == plan.global_rows)

    return (covers_prefix(plan_a) and covers_prefix(plan_b)
            and plan_a.global_rows == plan_b.global_rows)
