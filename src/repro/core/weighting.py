"""M1 — weighted loss/gradient aggregation (the HetSeq invariant).

The paper's master process computes ``sum_i(loss_i * w_i) / sum_i(w_i)``
over workers and broadcasts; gradients are averaged the same way. In
SPMD both collapse into a pair of psums (the weight psum is a scalar).

Two call styles:
  * global-view (pjit): the batch carries a per-token ``weights`` array
    (0 for dummy tokens); ``jnp.sum`` over the sharded batch is already
    the global weighted sum — XLA inserts the reduction. The helpers here
    are then just the final division (``finalize``).
  * manual (shard_map / benchmark simulation): ``psum_weighted`` performs
    the explicit collective on a named axis.

The invariant (tests/test_invariant.py encodes it property-based):
  for ANY split of a global batch across R workers with arbitrary
  per-worker counts (including zero => dummy rows, weight 0),
  aggregate(grads, weights) == grad of the single-process loss over the
  union of real rows.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def finalize(objective_sum: jnp.ndarray, weight_sum: jnp.ndarray
             ) -> jnp.ndarray:
    """Global weighted mean from (already globally summed) sums."""
    return objective_sum / jnp.maximum(weight_sum, 1e-9)


def scale_grads(grads: Any, weight_sum: jnp.ndarray) -> Any:
    """Divide a gradient-of-sums pytree by the total weight, once."""
    inv = 1.0 / jnp.maximum(weight_sum, 1e-9)
    return jax.tree.map(lambda g: g * inv.astype(g.dtype), grads)


def psum_weighted(value: jnp.ndarray, weight: jnp.ndarray,
                  axis) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit HetSeq aggregation on a named mesh axis.

    Returns (weighted mean over the axis, total weight). ``value`` is a
    per-shard *sum* (loss sum or grad-of-sum); ``weight`` the per-shard
    weight sum. Ranks holding only dummy data contribute weight 0 —
    the collective still fires (uniform SPMD), their payload is zeros.
    """
    total_v = jax.lax.psum(value, axis)
    total_w = jax.lax.psum(weight, axis)
    return total_v / jnp.maximum(total_w, 1e-9), total_w


def weighted_grad_psum(grads: Any, weight: jnp.ndarray, axis) -> Any:
    """Pytree version of psum_weighted for gradients."""
    total_w = jax.lax.psum(weight, axis)
    inv = 1.0 / jnp.maximum(total_w, 1e-9)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis) * inv, grads)


def simulate_workers(loss_fn, params, worker_batches: Sequence[Dict]
                     ) -> Tuple[jnp.ndarray, Any]:
    """Reference het-DP executor (no mesh): runs each worker's batch
    through ``loss_fn`` sequentially and aggregates with the HetSeq rule.
    Used by the equivalence benchmark and property tests.

    Each worker batch carries its own per-token weights; empty workers
    (all weights 0) still execute — the paper's dummy-batch path.
    Returns (loss, grads) that must equal single-process training on the
    union of all real rows.
    """
    def obj(p, b):
        o, w, _ = loss_fn(p, b)
        return o, w

    total_obj = 0.0
    total_w = 0.0
    grads_sum = None
    for b in worker_batches:
        (o, w), g = jax.value_and_grad(obj, has_aux=True)(params, b)
        total_obj += o
        total_w += w
        grads_sum = g if grads_sum is None else jax.tree.map(
            jnp.add, grads_sum, g)
    loss = finalize(jnp.asarray(total_obj), jnp.asarray(total_w))
    grads = scale_grads(grads_sum, jnp.asarray(total_w))
    return loss, grads
