"""M1 — weighted loss/gradient aggregation (the HetSeq invariant).

The paper's master process computes ``sum_i(loss_i * w_i) / sum_i(w_i)``
over workers and broadcasts; gradients are averaged the same way. In
SPMD both collapse into a pair of psums (the weight psum is a scalar).

Two call styles:
  * global-view (pjit): the batch carries a per-token ``weights`` array
    (0 for dummy tokens); ``jnp.sum`` over the sharded batch is already
    the global weighted sum — XLA inserts the reduction. The helpers here
    are then just the final division (``finalize``).
  * manual (shard_map / benchmark simulation): ``psum_weighted`` performs
    the explicit collective on a named axis.

The invariant (tests/test_invariant.py encodes it property-based):
  for ANY split of a global batch across R workers with arbitrary
  per-worker counts (including zero => dummy rows, weight 0),
  aggregate(grads, weights) == grad of the single-process loss over the
  union of real rows.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def finalize(objective_sum: jnp.ndarray, weight_sum: jnp.ndarray
             ) -> jnp.ndarray:
    """Global weighted mean from (already globally summed) sums."""
    return objective_sum / jnp.maximum(weight_sum, 1e-9)


def scale_grads(grads: Any, weight_sum: jnp.ndarray) -> Any:
    """Divide a gradient-of-sums pytree by the total weight, once."""
    inv = 1.0 / jnp.maximum(weight_sum, 1e-9)
    return jax.tree.map(lambda g: g * inv.astype(g.dtype), grads)


def psum_weighted(value: jnp.ndarray, weight: jnp.ndarray,
                  axis) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit HetSeq aggregation on a named mesh axis.

    Returns (weighted mean over the axis, total weight). ``value`` is a
    per-shard *sum* (loss sum or grad-of-sum); ``weight`` the per-shard
    weight sum. Ranks holding only dummy data contribute weight 0 —
    the collective still fires (uniform SPMD), their payload is zeros.
    """
    total_v = jax.lax.psum(value, axis)
    total_w = jax.lax.psum(weight, axis)
    return total_v / jnp.maximum(total_w, 1e-9), total_w


def weighted_grad_psum(grads: Any, weight: jnp.ndarray, axis) -> Any:
    """Pytree version of psum_weighted for gradients."""
    total_w = jax.lax.psum(weight, axis)
    inv = 1.0 / jnp.maximum(total_w, 1e-9)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis) * inv, grads)


def per_row_values(loss_fn, params, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], Any]:
    """Per-row objective/weight sums and gradients, vmapped.

    ``batch`` arrays carry a leading row dim; each row is evaluated as
    its own single-row batch, so row *i*'s outputs depend only on
    (params, row *i*) — never on which rank/buffer slot held it.
    Returns ``((o, w), grads)`` where every array gains that leading
    row dim. Building block of the *order-canonical* aggregation below.
    """
    def obj(p, row):
        b = jax.tree.map(lambda v: v[None], row)
        o, w, _ = loss_fn(p, b)
        return o, w

    gfn = jax.value_and_grad(obj, has_aux=True)
    return jax.vmap(gfn, in_axes=(None, 0))(params, batch)


def canonical_aggregate(per_row_obj: jnp.ndarray,
                        per_row_w: jnp.ndarray,
                        per_row_grads: Any
                        ) -> Tuple[jnp.ndarray, Any,
                                   jnp.ndarray, jnp.ndarray]:
    """Order-canonical HetSeq aggregation: sum per-row values along the
    leading (global-row-ordered) axis with a FIXED reduction tree.

    fp32 addition is not associative, so the SPMD step's aggregate is
    only tolerance-equal across different row->rank assignments (the
    partition changes the summation grouping). Summing *per-row* values
    in global-row order removes the plan from the float math entirely:
    any two runs that consume the same global rows produce bit-identical
    loss and gradients, whatever replans/re-meshes happened in between.
    The chaos benchmark (benchmarks/chaos_bench.py) builds its
    bitwise-checkable invariant on this.

    Returns ``(loss, scaled_grads, o_sum, w_sum)``.
    """
    o_sum = jnp.sum(per_row_obj, axis=0)
    w_sum = jnp.sum(per_row_w, axis=0)
    grads = jax.tree.map(lambda a: jnp.sum(a, axis=0), per_row_grads)
    return finalize(o_sum, w_sum), scale_grads(grads, w_sum), o_sum, w_sum


def simulate_workers(loss_fn, params, worker_batches: Sequence[Dict]
                     ) -> Tuple[jnp.ndarray, Any]:
    """Reference het-DP executor (no mesh): runs each worker's batch
    through ``loss_fn`` sequentially and aggregates with the HetSeq rule.
    Used by the equivalence benchmark and property tests.

    Each worker batch carries its own per-token weights; empty workers
    (all weights 0) still execute — the paper's dummy-batch path.
    Returns (loss, grads) that must equal single-process training on the
    union of all real rows.
    """
    def obj(p, b):
        o, w, _ = loss_fn(p, b)
        return o, w

    total_obj = 0.0
    total_w = 0.0
    grads_sum = None
    for b in worker_batches:
        (o, w), g = jax.value_and_grad(obj, has_aux=True)(params, b)
        total_obj += o
        total_w += w
        grads_sum = g if grads_sum is None else jax.tree.map(
            jnp.add, grads_sum, g)
    loss = finalize(jnp.asarray(total_obj), jnp.asarray(total_w))
    grads = scale_grads(grads_sum, jnp.asarray(total_w))
    return loss, grads
