"""Bucketed flat-buffer gradient reduction (the hot-path engine).

HetSeq's contribution is *exact* heterogeneous data parallelism, which
makes gradient synchronization the dominant cross-node cost. The legacy
reduction paths walked the gradient pytree leaf by leaf — dozens of
small, latency-bound DCN collectives per step, each quantized with its
own kernel launch, and the compressed path rebuilt the sum by gathering
ALL pods' full payloads (O(pods) receive bandwidth).

This module replaces that with PyTorch-DDP-style fixed-size buckets:

  * ``build_layout`` assigns every leaf a contiguous range of one
    conceptual fp32 stream, padded so it divides into ``num_buckets``
    buckets of exactly ``bucket_elems`` elements (leaves may span
    bucket boundaries — the bucket grid is fixed-size by construction,
    so the cross-link collective count is ``ceil(total_bytes /
    bucket_bytes)``-bounded regardless of how many leaves there are).
  * ``pack_buckets`` / ``unpack_buckets`` move a pytree into / out of
    the (num_buckets, bucket_elems) f32 bucket stack, preserving leaf
    dtypes. The error-feedback state lives in the SAME flat layout
    (one f32 array, not a pytree mirror).
  * ``exchange_buckets`` is the reduction schedule, applied to the
    whole bucket stack at once:

      uncompressed:  psum_scatter  ->  all_gather
      int8:          quantize(one fused kernel over ALL buckets)
                     -> all_to_all of fused int8 payload (values +
                        bit-cast scales, ONE collective)
                     -> fused dequant-accumulate kernel (receive side)
                     -> re-quantize shard sum -> all_gather payload

    Both variants issue exactly TWO cross-link collectives per step for
    the entire gradient, and both move ~2x shard bytes per rank on the
    link (reduce-scatter leg + broadcast leg) instead of O(ranks) full
    payloads. Error feedback captures both quantization stages: each
    rank keeps its own send-side residual, and the owner of a shard
    additionally keeps the residual of the re-quantized sum.

Caveat (documented, not hidden): packing concatenates leaves, so inside
a partially-manual shard_map region XLA may re-layout (data, model)-
sharded leaves into the replicated flat buffer. On the multi-pod
production mesh prefer ``hierarchical_reduce_bucketed``
(core/hierarchical.py), which reduce-scatters over the in-pod axis
first so only 1/data_size of the buffer exists per rank when the DCN
exchange runs.

Overlap mode (``HetConfig.overlap="buckets"``): ``exchange_buckets``
reduces the whole stack in two monolithic collectives, so the link and
the accelerator take turns idling. ``exchange_buckets_overlapped``
restructures the same schedule into a double-buffered per-bucket
pipeline: bucket *k+1*'s quantize/pack runs while bucket *k*'s
exchange is in flight, and an optional ``bucket_fn`` hook consumes each
reduced bucket as it lands (the train step fuses the per-bucket AdamW
update there — see optim/adam.py::apply_update_flat). The pipeline
costs 2 collectives *per bucket* instead of 2 total — the latency/
overlap trade a heterogeneous DCN link wants once buckets are sized to
hide the launch overhead. On current jax the pipeline is a
``lax.scan``; the old-jaxlib SPMD partitioner check-fails on
collectives inside a scan in a partially-manual region, so the compat
path unrolls the identical body in python (same dependency structure,
nb-times-larger HLO).

Checkpoint portability: the packed layout is a pure function of
(param tree, bucket_mb, reduction ranks, block size), so
``layout_record`` / ``layout_fingerprint`` serialize a versioned
description of the grid into checkpoint meta.json and
``checkpoint/repack.py`` translates packed state between any two grids
(or the pytree layout) through the flat stream — an overlap checkpoint
survives re-meshing.

Config: ``HetConfig.bucket_mb`` (0 = legacy per-leaf paths),
``HetConfig.quantize_impl`` selects the reference vs Pallas kernels,
``HetConfig.overlap`` selects the monolithic vs pipelined schedule.
Benchmarks: benchmarks/reduce_bench.py emits BENCH_reduce.json
(collective-launch counts, modeled DCN bytes, measured step times);
benchmarks/overlap_bench.py emits BENCH_overlap.json (modeled
per-bucket pipeline timeline + measured wall times).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import compression
from repro.kernels.quantize import ops as q_ops


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static assignment of pytree leaves to fixed-size f32 buckets."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]        # leaf start in the flat stream
    sizes: Tuple[int, ...]          # leaf element counts
    total: int                      # sum(sizes)
    bucket_elems: int
    num_buckets: int

    @property
    def padded_total(self) -> int:
        return self.num_buckets * self.bucket_elems

    @property
    def bucket_bytes(self) -> int:
        return self.bucket_elems * 4

    @property
    def total_bytes(self) -> int:
        return self.total * 4

    def error_shape(self, ranks: int) -> Tuple[int, int, int]:
        """Global shape of the flat error-feedback state: one bucket
        stack per rank along the reduction axis."""
        return (ranks, self.num_buckets, self.bucket_elems)


def build_layout(tree: Any, *, bucket_mb: float = 4.0,
                 multiple_of: int = 1) -> BucketLayout:
    """Compute the bucket grid for a pytree of arrays/ShapeDtypeStructs.

    ``bucket_mb`` is the target bucket payload in MiB of f32
    (PyTorch-DDP-style knob, ``HetConfig.bucket_mb``). ``bucket_elems``
    is rounded up to ``multiple_of`` so each bucket divides evenly into
    per-rank shards and quantization blocks (callers pass
    ranks * block_size for compressed exchanges).
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    offsets = []
    off = 0
    for n in sizes:
        offsets.append(off)
        off += n
    total = off
    if total == 0:
        raise ValueError("cannot bucket an empty pytree")
    target = max(1, int(bucket_mb * (1 << 20) / 4))
    bucket_elems = -(-target // multiple_of) * multiple_of
    # never more padding than one bucket: shrink to the padded total
    bucket_elems = min(bucket_elems,
                       -(-total // multiple_of) * multiple_of)
    num_buckets = -(-total // bucket_elems)
    return BucketLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                        offsets=tuple(offsets), sizes=sizes, total=total,
                        bucket_elems=bucket_elems, num_buckets=num_buckets)


def host_shard_extents(n: int, hosts: int) -> Tuple[Tuple[int, int], ...]:
    """Balanced contiguous ``[lo, hi)`` extents splitting ``n`` rows
    over ``hosts`` writers.

    The canonical split behind the v3 per-host checkpoint shards: host
    ``k`` of the save writes bucket rows ``extents[k]`` of each packed
    stack into its own ``arrays_host<k>.npz`` (checkpoint/checkpoint.py)
    and the extents are recorded in the layout record so a restore can
    validate reassembly. Also reused element-wise by
    ``checkpoint/repack.py`` to distribute the summed error-feedback
    residual across a NEW rank count (sum conserved, no rank parked
    with the whole residual). Empty extents (``hi == lo``) appear when
    ``hosts > n``.
    """
    if hosts <= 0:
        raise ValueError(f"hosts must be positive, got {hosts}")
    base, rem = divmod(int(n), hosts)
    out = []
    lo = 0
    for h in range(hosts):
        hi = lo + base + (1 if h < rem else 0)
        out.append((lo, hi))
        lo = hi
    return tuple(out)


# Bump when the serialized layout record changes incompatibly
# (checkpoint/repack.py validates it on restore).
LAYOUT_VERSION = 1

_FINGERPRINT_FIELDS = ("bucket_elems", "num_buckets", "total", "offsets",
                       "sizes", "shapes", "dtypes")


def layout_fingerprint(record: Dict) -> str:
    """Stable short hash of the grid-defining fields of a layout record.

    Two checkpoints with equal fingerprints hold interchangeable packed
    stacks; unequal fingerprints need a repack through the flat stream
    (checkpoint/repack.py). ``leaf_paths`` and ``version`` are excluded
    — they describe provenance, not the grid.
    """
    body = {k: record[k] for k in _FINGERPRINT_FIELDS if k in record}
    return hashlib.sha1(
        json.dumps(body, sort_keys=True).encode()).hexdigest()[:16]


def layout_record(layout: BucketLayout,
                  leaf_paths: Optional[Sequence[str]] = None,
                  hosts: Optional[int] = None) -> Dict:
    """JSON-able versioned description of a :class:`BucketLayout`.

    Saved into checkpoint ``meta.json`` so a restore can (a) detect a
    grid mismatch by fingerprint and (b) strictly validate the flat
    stream length when repacking. ``leaf_paths`` (the escaped
    checkpoint key path of every leaf, see ``repack.path_key``) records
    which parameter each stream range belongs to. ``hosts`` records the
    v3 per-host shard split: ``host_extents[k]`` is the bucket-row
    range host ``k`` writes into its own ``arrays_host<k>.npz``.
    Neither is part of the fingerprint — they describe provenance and
    the write-time sharding, not the grid.
    """
    rec: Dict[str, Any] = {
        "version": LAYOUT_VERSION,
        "bucket_elems": int(layout.bucket_elems),
        "num_buckets": int(layout.num_buckets),
        "total": int(layout.total),
        "offsets": [int(o) for o in layout.offsets],
        "sizes": [int(s) for s in layout.sizes],
        "shapes": [list(s) for s in layout.shapes],
        "dtypes": [str(jnp.dtype(d)) for d in layout.dtypes],
    }
    if leaf_paths is not None:
        rec["leaf_paths"] = [str(p) for p in leaf_paths]
    if hosts is not None:
        rec["hosts"] = int(hosts)
        rec["host_extents"] = [
            [lo, hi]
            for lo, hi in host_shard_extents(layout.num_buckets, hosts)]
    rec["fingerprint"] = layout_fingerprint(rec)
    return rec


def layout_from_record(record: Dict, treedef: Any = None) -> BucketLayout:
    """Rebuild a :class:`BucketLayout` from its serialized record.

    ``treedef`` (from the restoring process's own param tree) is needed
    only for ``unpack_buckets``; stream-level repacking works without
    it. Raises on unknown record versions.
    """
    version = int(record.get("version", 0))
    if version > LAYOUT_VERSION:
        raise ValueError(
            f"bucket layout record version {version} is newer than this "
            f"build supports ({LAYOUT_VERSION})")
    return BucketLayout(
        treedef=treedef,
        shapes=tuple(tuple(int(d) for d in s) for s in record["shapes"]),
        dtypes=tuple(jnp.dtype(d) for d in record["dtypes"]),
        offsets=tuple(int(o) for o in record["offsets"]),
        sizes=tuple(int(s) for s in record["sizes"]),
        total=int(record["total"]),
        bucket_elems=int(record["bucket_elems"]),
        num_buckets=int(record["num_buckets"]))


def pack_buckets(tree: Any, layout: BucketLayout) -> jnp.ndarray:
    """Pytree -> (num_buckets, bucket_elems) f32 bucket stack."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(layout.sizes):
        raise ValueError(
            f"tree has {len(leaves)} leaves, layout expects "
            f"{len(layout.sizes)}")
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])
    if flat.shape[0] != layout.total:
        raise ValueError(
            f"tree holds {flat.shape[0]} elements, layout expects "
            f"{layout.total}")
    flat = compat.pad_trailing(flat, layout.padded_total - layout.total)
    return flat.reshape(layout.num_buckets, layout.bucket_elems)


def unpack_buckets(buckets: jnp.ndarray, layout: BucketLayout) -> Any:
    """(num_buckets, bucket_elems) -> pytree with original dtypes."""
    flat = buckets.reshape(-1)
    leaves = [
        flat[off:off + n].reshape(shape).astype(dtype)
        for off, n, shape, dtype in zip(layout.offsets, layout.sizes,
                                        layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def init_error_buckets(layout: BucketLayout) -> jnp.ndarray:
    """Per-rank flat error-feedback state (one rank's slice)."""
    return jnp.zeros((layout.num_buckets, layout.bucket_elems),
                     jnp.float32)


# --------------------------------------------------------------------------
# flat views of per-leaf structure (for the packed optimizer path)
# --------------------------------------------------------------------------


def decay_mask(layout: BucketLayout) -> jnp.ndarray:
    """(num_buckets, bucket_elems) int8 weight-decay mask.

    1 for elements whose source leaf is a matrix (ndim >= 2 — the
    decay-matrices-only AdamW rule in optim/adam.py), 0 for vector /
    scalar leaves and for bucket padding. Lets the flat-view optimizer
    (``apply_update_flat``) reproduce the per-leaf decay policy without
    unpacking. int8 storage: the mask is a param-sized replicated
    constant — 1 byte/param, cast to f32 at the single multiply site.
    """
    import numpy as np

    mask = np.zeros(layout.padded_total, np.int8)
    for off, n, shape in zip(layout.offsets, layout.sizes, layout.shapes):
        if len(shape) >= 2:
            mask[off:off + n] = 1
    return jnp.asarray(
        mask.reshape(layout.num_buckets, layout.bucket_elems))


def segment_ids(layout: BucketLayout) -> jnp.ndarray:
    """(num_buckets, bucket_elems) int32 leaf index per element.

    Bucket padding maps to ``len(layout.sizes)`` (one past the last
    leaf) so per-leaf segment reductions (LAMB trust ratios) can drop
    it. Leaves may span bucket boundaries — segment reductions over the
    flattened stack see each leaf whole regardless.
    """
    import numpy as np

    ids = np.full(layout.padded_total, len(layout.sizes), np.int32)
    for i, (off, n) in enumerate(zip(layout.offsets, layout.sizes)):
        ids[off:off + n] = i
    return jnp.asarray(
        ids.reshape(layout.num_buckets, layout.bucket_elems))


# --------------------------------------------------------------------------
# the exchange schedule
# --------------------------------------------------------------------------


def exchange_buckets(
    buckets: jnp.ndarray,
    err: Optional[jnp.ndarray] = None,
    *,
    axis: compat.AxisNames,
    axis_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference",
    interpret: bool = False,
    total: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Inside shard_map(manual over ``axis``): all-reduce the stack.

    ``buckets``: (num_buckets, bucket_elems) — this rank's gradient
    contribution, packed. ``err``: same shape, this rank's persistent
    error-feedback state (compressed mode only). Returns the globally
    summed stack and the new error state.

    Exactly two collectives cross the link regardless of bucket or leaf
    count; compressed mode keeps int8 (+bit-cast scales) on the wire in
    both directions.

    ``total``: real (pre-padding) element count of the stream
    (``layout.total``). When given, compressed mode skips the quantize
    kernel over the all-padding tail blocks — their payload is
    constant zeros, which a native ragged exchange never puts on the
    wire (``modeled_link_bytes`` counts data blocks only). Only valid
    when the stack holds the full stream in flat order (NOT the
    data-scattered shard inside ``hierarchical_reduce_bucketed``,
    where the padding tail lives on a subset of ranks).
    """
    nb, be = buckets.shape
    p = axis_size
    if be % p:
        raise ValueError(f"bucket_elems {be} not divisible by axis size "
                         f"{p}; build the layout with multiple_of={p}")
    shard = be // p
    x = buckets.reshape(nb, p, shard)

    if not compress:
        sh = jax.lax.psum_scatter(x, axis, scatter_dimension=1,
                                  tiled=False)              # (nb, shard)
        onehot = (None if compat.NATIVE_MANUAL_COLLECTIVES
                  else compat.manual_axis_onehot(axis, p, tie=buckets))
        full = compat.manual_all_gather(sh, axis, p, onehot)
        return jnp.moveaxis(full, 0, 1).reshape(nb, be), err

    if shard % block_size:
        raise ValueError(
            f"shard {shard} not divisible by block_size {block_size}; "
            f"build the layout with multiple_of={p * block_size}")
    ns = shard // block_size

    want_err = err is not None
    corrected = x + (err.reshape(nb, p, shard) if want_err else 0.0)
    # collective-free on native jax (axis_index); one tiny identity
    # scatter on the emulated stack
    onehot = compat.manual_axis_onehot(axis, p, tie=buckets)
    if key is not None:
        # decorrelate stochastic rounding across ranks
        key = jax.random.fold_in(key, jnp.argmax(onehot).astype(jnp.int32))

    # ONE fused quantize over the whole concatenated bucket stack.
    # The (nb, p, shard) layout flattens in stream order, so the
    # all-padding tail blocks (past ``total``) form a suffix of the
    # block rows — skip the kernel over them and emit constant-zero
    # payload (dequantizes to exactly 0.0, same as quantizing zeros).
    n_rows = nb * p * ns
    d_rows = (n_rows if total is None
              else max(1, min(n_rows, -(-total // block_size))))
    if d_rows < n_rows:
        q_d, s_d = q_ops.quantize_int8(
            corrected.reshape(n_rows, block_size)[:d_rows],
            block_size=block_size, key=key, impl=impl,
            interpret=interpret)
        q = jnp.concatenate(
            [q_d, jnp.zeros((n_rows - d_rows, block_size), jnp.int8)])
        s = jnp.concatenate([s_d, jnp.zeros((n_rows - d_rows,),
                                            jnp.float32)])
    else:
        q, s = q_ops.quantize_int8(corrected, block_size=block_size,
                                   key=key, impl=impl,
                                   interpret=interpret)
    # q: (nb*p*ns, block), s: (nb*p*ns,)
    if want_err:
        deq_local = (q.astype(jnp.float32) *
                     s[:, None]).reshape(nb, p, shard)
        new_err = corrected - deq_local      # stage-1 residual, all shards
        if d_rows < n_rows:
            # the all-padding tail carries no signal: pin its error
            # slots to zero (they are zero on every reachable state —
            # init is zero and zero grads leave zero residual — this
            # just refuses to carry garbage from a corrupted restore).
            # The untrimmed per-bucket pipeline preserves a zero tail
            # too, so both schedules agree bitwise on reachable states.
            ner = new_err.reshape(n_rows, block_size)
            new_err = jnp.concatenate(
                [ner[:d_rows],
                 jnp.zeros((n_rows - d_rows, block_size), jnp.float32)]
            ).reshape(nb, p, shard)

    payload = compression.fuse_payload(
        q.reshape(nb, p, ns, block_size), s.reshape(nb, p, ns))
    # rank-major leading axis for the exchange: row j = message to rank j
    wire = jnp.moveaxis(payload, 1, 0)       # (p, nb, ns, block+4)
    rx = compat.manual_all_to_all(wire, axis, p, onehot)  # row j = from j
    q_x, s_x = compression.split_payload(rx, block_size)

    # fused dequant-accumulate over the peer axis (receive side)
    shard_sum = q_ops.dequant_accum(
        q_x.reshape(p, nb * ns, block_size), s_x.reshape(p, nb * ns),
        impl=impl, interpret=interpret)      # (nb*ns, block)

    # re-quantize the summed shard for the broadcast leg
    q2, s2 = q_ops.quantize_int8(shard_sum, block_size=block_size,
                                 key=None, impl=impl, interpret=interpret)
    if want_err:
        deq2 = (q2.astype(jnp.float32) * s2[:, None]).reshape(nb, shard)
        resid2 = shard_sum.reshape(nb, shard) - deq2
        # stage-2 residual belongs to this shard's owner (= this rank):
        # scatter it into our slot of the flat error state
        new_err = new_err + resid2[:, None, :] * onehot[None, :, None]

    payload2 = compression.fuse_payload(
        q2.reshape(nb, ns, block_size), s2.reshape(nb, ns))
    g2 = compat.manual_all_gather(payload2, axis, p, onehot)
    qg, sg = compression.split_payload(g2, block_size)
    full = qg.astype(jnp.float32) * sg[..., None]      # (p, nb, ns, B)
    full = jnp.moveaxis(full, 0, 1).reshape(nb, be)
    return full, (new_err.reshape(nb, be) if want_err else None)


# --------------------------------------------------------------------------
# the overlapped (double-buffered per-bucket) exchange pipeline
# --------------------------------------------------------------------------


def prepare_bucket(
    x_k: jnp.ndarray,
    err_k: Optional[jnp.ndarray],
    *,
    compress: bool,
    block_size: int,
    key: Optional[jax.Array],
    impl: str,
    interpret: bool,
) -> Tuple[Any, Optional[jnp.ndarray]]:
    """Send-side leg for ONE bucket: error-correct + quantize + fuse.

    ``x_k``: (p, shard) — bucket *k* reshaped rank-major. Returns the
    wire-ready payload plus the stage-1 residual (compressed mode with
    error feedback). This is the pipeline stage that runs for bucket
    *k+1* while bucket *k*'s exchange is in flight.
    """
    if not compress:
        return x_k, None
    p, shard = x_k.shape
    ns = shard // block_size
    corrected = x_k + (err_k if err_k is not None else 0.0)
    q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key,
                               impl=impl, interpret=interpret)
    resid1 = None
    if err_k is not None:
        deq_local = (q.astype(jnp.float32) * s[:, None]).reshape(p, shard)
        resid1 = corrected - deq_local
    payload = compression.fuse_payload(
        q.reshape(p, ns, block_size), s.reshape(p, ns))  # (p, ns, B+4)
    return payload, resid1


def exchange_prepared_bucket(
    payload: Any,
    resid1: Optional[jnp.ndarray],
    *,
    axis: compat.AxisNames,
    axis_size: int,
    compress: bool,
    block_size: int,
    impl: str,
    interpret: bool,
    onehot: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Link + receive-side legs for ONE prepared bucket.

    Returns the globally summed (bucket_elems,) bucket and its new
    error slice (p, shard). Mirrors ``exchange_buckets`` exactly on a
    single bucket, so per-bucket results are bitwise identical to the
    corresponding slice of the monolithic exchange, given ``key=None``
    and a zero error tail in the padding region (true on every
    reachable state: the tail starts zero, zero grads leave zero
    residual, and the monolithic trim pins it to zero — only the
    per-bucket pipeline cannot skip tail blocks, since its scan body
    must stay uniform across buckets).
    """
    p = axis_size
    if not compress:
        sh = jax.lax.psum_scatter(payload, axis, scatter_dimension=0,
                                  tiled=False)             # (shard,)
        full = compat.manual_all_gather(sh, axis, p, onehot)
        return full.reshape(-1), None

    ns = payload.shape[1]
    rx = compat.manual_all_to_all(payload, axis, p, onehot)
    q_x, s_x = compression.split_payload(rx, block_size)
    shard_sum = q_ops.dequant_accum(
        q_x.reshape(p, ns, block_size), s_x.reshape(p, ns),
        impl=impl, interpret=interpret)                    # (ns, B)
    q2, s2 = q_ops.quantize_int8(shard_sum, block_size=block_size,
                                 key=None, impl=impl, interpret=interpret)
    new_err = None
    if resid1 is not None:
        deq2 = (q2.astype(jnp.float32) * s2[:, None]).reshape(-1)
        resid2 = shard_sum.reshape(-1) - deq2              # (shard,)
        new_err = resid1 + resid2[None, :] * onehot[:, None]
    payload2 = compression.fuse_payload(
        q2.reshape(ns, block_size), s2)
    g2 = compat.manual_all_gather(payload2, axis, p, onehot)
    qg, sg = compression.split_payload(g2, block_size)
    full = qg.astype(jnp.float32) * sg[..., None]          # (p, ns, B)
    return full.reshape(-1), new_err


def run_overlapped_pipeline(
    num_buckets: int,
    prep,
    exchange,
    *,
    raw: jnp.ndarray,
    err: Optional[jnp.ndarray] = None,
    bucket_fn=None,
    fn_carry: Any = None,
    bucket_xs: Any = None,
) -> Tuple[Any, Optional[jnp.ndarray], Any]:
    """THE double-buffered per-bucket pipeline driver (shared by the
    flat and 3-level hierarchical schedules).

    ``prep(k, raw_k, err_k)`` builds bucket *k*'s wire-ready state from
    ``raw[k]`` / ``err[k]``; ``exchange(prepared)`` runs its collective
    leg(s) and returns ``(reduced_k, new_err_k | None)``. Iteration *k*
    calls ``prep`` for bucket *k+1* before exchanging bucket *k* — the
    prepared state in the carry is the double buffer — and hands each
    reduced bucket to ``bucket_fn(carry, reduced_k, xs_k, k)`` the
    moment it lands (default: passthrough). The last bucket exchanges
    in an epilogue so no dead prepare is ever issued.

    On current jax the steady state is a ``lax.scan``; the old-jaxlib
    SPMD partitioner check-fails on collectives inside a scan in a
    partially-manual region, so the compat path unrolls the identical
    body in python (same dependency structure, nb-times-larger HLO).

    Returns (stacked bucket_fn outputs, stacked new error slices or
    None, final bucket_fn carry).
    """
    nb = num_buckets
    want_err = err is not None
    if bucket_fn is None:
        bucket_fn = lambda carry, red, xs_k, k: (carry, red)  # noqa: E731

    def exch_one(prepared, fc, bx_k, k):
        red_k, nerr_k = exchange(prepared)
        fc, out_k = bucket_fn(fc, red_k, bx_k, k)
        if nerr_k is None:
            nerr_k = jnp.zeros((), jnp.float32)     # uniform scan output
        return fc, out_k, nerr_k

    def body(carry, xs_k):
        (prepared, fc), (k, raw_next, err_next, bx_k) = carry, xs_k
        # double buffer: bucket k+1's send-side leg is issued while
        # bucket k's exchange is (logically) in flight — it depends
        # only on the raw bucket, never on bucket k's landing
        nxt = prep(k + 1, raw_next, err_next)
        fc, out_k, nerr_k = exch_one(prepared, fc, bx_k, k)
        return (nxt, fc), (out_k, nerr_k)

    def bx_at(k):
        return (jax.tree.map(lambda a: a[k], bucket_xs)
                if bucket_xs is not None else None)

    carry = (prep(0, raw[0], err[0] if want_err else None), fn_carry)
    outs_h = nerrs_h = None
    if nb > 1 and compat.NATIVE_MANUAL_COLLECTIVES:
        xs = (jnp.arange(nb - 1), raw[1:],
              err[1:] if want_err else jnp.zeros((nb - 1,), jnp.float32),
              jax.tree.map(lambda a: a[:nb - 1], bucket_xs)
              if bucket_xs is not None
              else jnp.zeros((nb - 1,), jnp.float32))
        carry, (outs_h, nerrs_h) = jax.lax.scan(
            lambda c, s: body(c, (s[0], s[1],
                                  s[2] if want_err else None,
                                  s[3] if bucket_xs is not None else None)),
            carry, xs)
    elif nb > 1:
        head_list = []
        for k in range(nb - 1):
            carry, head_k = body(
                carry, (k, raw[k + 1],
                        err[k + 1] if want_err else None, bx_at(k)))
            head_list.append(head_k)
        outs_h, nerrs_h = jax.tree.map(lambda *ls: jnp.stack(ls),
                                       *head_list)
    prepared, fc = carry
    fc, out_last, nerr_last = exch_one(prepared, fc, bx_at(nb - 1),
                                       nb - 1)
    if outs_h is None:
        outs = jax.tree.map(lambda l: l[None], out_last)
        nerrs = nerr_last[None]
    else:
        outs = jax.tree.map(lambda h, l: jnp.concatenate([h, l[None]]),
                            outs_h, out_last)
        nerrs = jnp.concatenate([nerrs_h, nerr_last[None]])
    return outs, (nerrs if want_err else None), fc


def exchange_buckets_overlapped(
    buckets: jnp.ndarray,
    err: Optional[jnp.ndarray] = None,
    *,
    axis: compat.AxisNames,
    axis_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference",
    interpret: bool = False,
    bucket_fn=None,
    fn_carry: Any = None,
    bucket_xs: Any = None,
) -> Tuple[Any, Optional[jnp.ndarray], Any]:
    """Double-buffered per-bucket reduction pipeline, fused hook.

    Same contract as :func:`exchange_buckets`, restructured as a scan
    over buckets with software pipelining: iteration *k* exchanges the
    payload prepared during iteration *k-1* (so bucket *k+1*'s
    quantize/pack overlaps bucket *k*'s in-flight collective — the
    double buffer is the scan carry) and hands bucket *k*'s reduced
    payload to ``bucket_fn`` the moment it lands.

    ``bucket_fn(carry, reduced_k, xs_k, k) -> (carry, out_k)`` is the
    fusion hook — the train step applies the per-bucket flat-view
    optimizer update here (optim/adam.py::apply_update_flat), with the
    packed param/moment bucket slices arriving via ``bucket_xs`` (a
    pytree whose leaves have leading dim num_buckets). The default hook
    passes the reduced bucket through, so the result is the reduced
    (num_buckets, bucket_elems) stack.

    Per-step stochastic-rounding keys are decorrelated per bucket via
    ``fold_in(key, k)`` (so int8 results with a key differ from the
    monolithic single-fold schedule; with ``key=None`` both schedules
    quantize identical blocks and agree bitwise).

    Returns ``(stacked bucket_fn outputs, new error state, final
    bucket_fn carry)``. Costs 2 collectives per bucket (the price of
    overlap) vs 2 total for the monolithic schedule.
    """
    nb, be = buckets.shape
    p = axis_size
    if be % p:
        raise ValueError(f"bucket_elems {be} not divisible by axis size "
                         f"{p}; build the layout with multiple_of={p}")
    shard = be // p
    if compress and shard % block_size:
        raise ValueError(
            f"shard {shard} not divisible by block_size {block_size}; "
            f"build the layout with multiple_of={p * block_size}")
    x = buckets.reshape(nb, p, shard)
    want_err = compress and err is not None
    e = err.reshape(nb, p, shard) if want_err else None
    onehot = compat.manual_axis_onehot(axis, p, tie=buckets)

    def prep(k, raw_k, err_k):
        bkey = (jax.random.fold_in(key, k) if (compress and key is not None)
                else None)
        if compress and bkey is not None:
            bkey = jax.random.fold_in(
                bkey, jnp.argmax(onehot).astype(jnp.int32))
        return prepare_bucket(raw_k, err_k, compress=compress,
                              block_size=block_size, key=bkey, impl=impl,
                              interpret=interpret)

    def exchange(prepared):
        payload, resid1 = prepared
        return exchange_prepared_bucket(
            payload, resid1, axis=axis, axis_size=p, compress=compress,
            block_size=block_size, impl=impl, interpret=interpret,
            onehot=onehot)

    outs, nerrs, fc = run_overlapped_pipeline(
        nb, prep, exchange, raw=x, err=e, bucket_fn=bucket_fn,
        fn_carry=fn_carry, bucket_xs=bucket_xs)
    new_err = nerrs.reshape(nb, be) if want_err else None
    return outs, new_err, fc


# --------------------------------------------------------------------------
# backward-overlap readiness schedule (HetConfig.overlap="backward")
#
# The per-bucket pipeline above starts after the full gradient tree
# exists — the DCN link idles through the entire backward pass. The
# flush pipeline instead issues each bucket's exchange the moment its
# last contributing gradient lands during backprop. Readiness is a
# pure layout property: each leaf (or per-layer slice of a stacked
# leaf) occupies a contiguous range of the flat stream (the same
# segment structure ``segment_ids`` exposes), and each range is
# annotated with the backward stage at which its gradient becomes
# final (models/transformer.py stage numbering: 0 = head, s = layer
# L-s, L+1 = embed). A bucket is ready at the LATEST stage of any
# element it contains.
# --------------------------------------------------------------------------


def bucket_readiness(layout: BucketLayout,
                     leaf_pieces: Sequence[Sequence[Tuple[int, int, int]]]
                     ) -> Tuple[int, ...]:
    """Per-bucket backward stage at which the bucket is flushable.

    ``leaf_pieces[i]`` describes leaf *i* (in ``layout`` flatten order)
    as ``(offset_within_leaf, n_elems, stage)`` ranges — one piece for
    an ordinary leaf, one per layer for a stacked ``(L, ...)`` leaf
    (the model's layer partition). Bucket *k*'s readiness is the max
    stage over the real elements in ``[k*bucket_elems, (k+1)*
    bucket_elems)``; padding never delays a flush. Pieces must tile
    each leaf exactly.
    """
    if len(leaf_pieces) != len(layout.sizes):
        raise ValueError(
            f"leaf_pieces has {len(leaf_pieces)} entries, layout has "
            f"{len(layout.sizes)} leaves")
    ready = [0] * layout.num_buckets
    be = layout.bucket_elems
    for i, (off, size) in enumerate(zip(layout.offsets, layout.sizes)):
        covered = 0
        for p_off, n, stage in leaf_pieces[i]:
            if p_off != covered:
                raise ValueError(
                    f"leaf {i}: pieces must tile the leaf contiguously "
                    f"(expected offset {covered}, got {p_off})")
            covered += n
            start = off + p_off
            for k in range(start // be, (start + n - 1) // be + 1):
                if stage > ready[k]:
                    ready[k] = stage
        if covered != size:
            raise ValueError(
                f"leaf {i}: pieces cover {covered} of {size} elements")
    return tuple(ready)


class BucketFlushPipeline:
    """Double-buffered per-bucket exchange driven by backward-stage
    readiness — the ``overlap="backward"`` schedule.

    Same dependency structure as :func:`run_overlapped_pipeline`
    (bucket *j*'s send-side prep is issued before the previous ready
    bucket's exchange, so the prep overlaps the in-flight collective),
    but buckets are fed in READINESS order as the staged backward
    lands their gradients, instead of 0..nb-1 after the full tree
    exists. The driver is plain python over traced values: the staged
    backward is an unrolled program (models/transformer.py), so the
    flush schedule is static.

    ``prep(k, raw_k)`` builds bucket *k*'s wire-ready state (quantize/
    pack — no collectives); ``exchange(k, prepared)`` runs its
    collective leg(s) and returns ``(reduced_k, new_err_k | None)``;
    ``bucket_fn(carry, reduced_k, k) -> (carry, out_k)`` consumes each
    reduced bucket the moment it lands (the train step fuses the
    flat-view optimizer update here). Per-bucket results are bitwise
    identical to the after-backward pipeline — each bucket's exchange
    is independent, so the issue ORDER cannot change values.
    """

    def __init__(self, readiness: Sequence[int], prep, exchange, *,
                 bucket_fn=None, fn_carry: Any = None):
        self.readiness = tuple(int(s) for s in readiness)
        self.num_buckets = len(self.readiness)
        self._prep = prep
        self._exchange = exchange
        self._bucket_fn = bucket_fn or (
            lambda carry, red, k: (carry, red))
        self.fn_carry = fn_carry
        self._by_stage: Dict[int, list] = {}
        for k, s in enumerate(self.readiness):
            self._by_stage.setdefault(s, []).append(k)
        self._pending: Optional[Tuple[int, Any]] = None
        self._outs: Dict[int, Any] = {}
        self._errs: Dict[int, Any] = {}
        self._flushed: set = set()

    def _exchange_pending(self) -> None:
        k, prepared = self._pending
        self._pending = None
        red_k, nerr_k = self._exchange(k, prepared)
        self.fn_carry, out_k = self._bucket_fn(self.fn_carry, red_k, k)
        self._outs[k] = out_k
        if nerr_k is not None:
            self._errs[k] = nerr_k

    def flush_ready_buckets(self, stage: int, raw_of) -> None:
        """Feed every bucket whose readiness == ``stage``.

        ``raw_of(k)`` returns bucket *k*'s raw payload (the caller's
        stream buffer slice) at flush time. For each ready bucket the
        pipeline preps it FIRST, then exchanges the previously prepped
        bucket — the double buffer: prep *j+1* is issued while bucket
        *j*'s exchange is (logically) in flight.
        """
        for k in self._by_stage.get(int(stage), ()):
            if k in self._flushed:
                raise ValueError(f"bucket {k} flushed twice")
            self._flushed.add(k)
            nxt = (k, self._prep(k, raw_of(k)))
            if self._pending is not None:
                self._exchange_pending()
            self._pending = nxt

    def finish(self) -> Tuple[list, Optional[list], Any]:
        """Exchange the last prepped bucket and assemble results in
        BUCKET-INDEX order (the flush order was readiness order).
        Returns (outs[k] list, errs[k] list or None, bucket_fn carry).
        """
        if self._pending is not None:
            self._exchange_pending()
        if len(self._flushed) != self.num_buckets:
            missing = sorted(set(range(self.num_buckets)) - self._flushed)
            raise ValueError(
                f"finish() before buckets {missing} were flushed — the "
                f"staged backward must visit every readiness stage")
        outs = [self._outs[k] for k in range(self.num_buckets)]
        errs = ([self._errs[k] for k in range(self.num_buckets)]
                if self._errs else None)
        return outs, errs, self.fn_carry


# --------------------------------------------------------------------------
# analytic link-byte model (for §Roofline and the reduction benchmark)
# --------------------------------------------------------------------------


def modeled_link_bytes(layout: BucketLayout, ranks: int, *,
                       compress: bool = False,
                       block_size: int = 256) -> int:
    """Per-rank bytes on the reduction link for one bucketed exchange.

    Uncompressed: reduce-scatter + all-gather each move (p-1)/p of the
    padded buffer per rank. Compressed: the all_to_all sends (p-1)/p of
    the fused int8 payload, the all-gather broadcast leg forwards
    (p-1) shard payloads; only DATA blocks count — the all-padding
    tail blocks of the last bucket are constant zeros that a native
    ragged exchange never transmits (and ``exchange_buckets`` skips
    quantizing), so bucketed int8 never models more bytes than the
    per-leaf int8 walk (sum of per-leaf block counts >= the stream's
    block count). This models the *native* schedule; the psum-based
    CPU emulation in compat.py moves more bytes but issues the same
    number of collectives.
    """
    p = ranks
    n = layout.padded_total
    if not compress:
        return int(2 * (p - 1) / p * n * 4)
    blocks = -(-layout.total // block_size)    # data blocks only
    payload = blocks * (block_size + 4)        # int8 values + fused scales
    a2a = (p - 1) / p * payload
    ag = (p - 1) / p * payload                 # p shard payloads, ring leg
    return int(a2a + ag)


def modeled_bucket_link_bytes(layout: BucketLayout, ranks: int, k: int, *,
                              compress: bool = False,
                              block_size: int = 256) -> int:
    """Per-rank link bytes for bucket ``k`` of the per-bucket pipeline.

    Same model as :func:`modeled_link_bytes` applied to one bucket;
    summed over buckets it reproduces the monolithic total (the
    pipeline moves the same bytes, just in nb back-to-back messages).
    """
    p = ranks
    if not compress:
        return int(2 * (p - 1) / p * layout.bucket_elems * 4)
    start = k * layout.bucket_elems
    data = max(0, min(layout.total - start, layout.bucket_elems))
    blocks = -(-data // block_size)
    return int(2 * (p - 1) / p * blocks * (block_size + 4))


def modeled_per_leaf_bytes(tree: Any, ranks: int, *,
                           compress: bool = False,
                           block_size: int = 256) -> int:
    """Per-rank link bytes for the legacy per-leaf schedule.

    Uncompressed: one psum per leaf (ring all-reduce, ~2(p-1)/p of the
    leaf). Compressed (legacy _cross_pod_reduce): all-gather of EVERY
    rank's full quantized payload — (p-1) full payloads per rank, the
    O(ranks) receive-bandwidth term the bucketed schedule removes.
    """
    p = ranks
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        if not compress:
            total += int(2 * (p - 1) / p * n * 4)
        else:
            blocks = -(-n // block_size)
            payload = blocks * block_size + blocks * 4
            total += int((p - 1) * payload)
    return total
