"""Bucketed flat-buffer gradient reduction (the hot-path engine).

HetSeq's contribution is *exact* heterogeneous data parallelism, which
makes gradient synchronization the dominant cross-node cost. The legacy
reduction paths walked the gradient pytree leaf by leaf — dozens of
small, latency-bound DCN collectives per step, each quantized with its
own kernel launch, and the compressed path rebuilt the sum by gathering
ALL pods' full payloads (O(pods) receive bandwidth).

This module replaces that with PyTorch-DDP-style fixed-size buckets:

  * ``build_layout`` assigns every leaf a contiguous range of one
    conceptual fp32 stream, padded so it divides into ``num_buckets``
    buckets of exactly ``bucket_elems`` elements (leaves may span
    bucket boundaries — the bucket grid is fixed-size by construction,
    so the cross-link collective count is ``ceil(total_bytes /
    bucket_bytes)``-bounded regardless of how many leaves there are).
  * ``pack_buckets`` / ``unpack_buckets`` move a pytree into / out of
    the (num_buckets, bucket_elems) f32 bucket stack, preserving leaf
    dtypes. The error-feedback state lives in the SAME flat layout
    (one f32 array, not a pytree mirror).
  * ``exchange_buckets`` is the reduction schedule, applied to the
    whole bucket stack at once:

      uncompressed:  psum_scatter  ->  all_gather
      int8:          quantize(one fused kernel over ALL buckets)
                     -> all_to_all of fused int8 payload (values +
                        bit-cast scales, ONE collective)
                     -> fused dequant-accumulate kernel (receive side)
                     -> re-quantize shard sum -> all_gather payload

    Both variants issue exactly TWO cross-link collectives per step for
    the entire gradient, and both move ~2x shard bytes per rank on the
    link (reduce-scatter leg + broadcast leg) instead of O(ranks) full
    payloads. Error feedback captures both quantization stages: each
    rank keeps its own send-side residual, and the owner of a shard
    additionally keeps the residual of the re-quantized sum.

Caveat (documented, not hidden): packing concatenates leaves, so inside
a partially-manual shard_map region XLA may re-layout (data, model)-
sharded leaves into the replicated flat buffer. On the multi-pod
production mesh prefer ``hierarchical_reduce_bucketed``
(core/hierarchical.py), which reduce-scatters over the in-pod axis
first so only 1/data_size of the buffer exists per rank when the DCN
exchange runs.

Config: ``HetConfig.bucket_mb`` (0 = legacy per-leaf paths),
``HetConfig.quantize_impl`` selects the reference vs Pallas kernels.
Benchmark: benchmarks/reduce_bench.py emits BENCH_reduce.json with
collective-launch counts, modeled DCN bytes and measured step times for
per-leaf vs bucketed on the 8-device host mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import compression
from repro.kernels.quantize import ops as q_ops


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static assignment of pytree leaves to fixed-size f32 buckets."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]        # leaf start in the flat stream
    sizes: Tuple[int, ...]          # leaf element counts
    total: int                      # sum(sizes)
    bucket_elems: int
    num_buckets: int

    @property
    def padded_total(self) -> int:
        return self.num_buckets * self.bucket_elems

    @property
    def bucket_bytes(self) -> int:
        return self.bucket_elems * 4

    @property
    def total_bytes(self) -> int:
        return self.total * 4

    def error_shape(self, ranks: int) -> Tuple[int, int, int]:
        """Global shape of the flat error-feedback state: one bucket
        stack per rank along the reduction axis."""
        return (ranks, self.num_buckets, self.bucket_elems)


def build_layout(tree: Any, *, bucket_mb: float = 4.0,
                 multiple_of: int = 1) -> BucketLayout:
    """Compute the bucket grid for a pytree of arrays/ShapeDtypeStructs.

    ``bucket_mb`` is the target bucket payload in MiB of f32
    (PyTorch-DDP-style knob, ``HetConfig.bucket_mb``). ``bucket_elems``
    is rounded up to ``multiple_of`` so each bucket divides evenly into
    per-rank shards and quantization blocks (callers pass
    ranks * block_size for compressed exchanges).
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(math.prod(s)) for s in shapes)
    offsets = []
    off = 0
    for n in sizes:
        offsets.append(off)
        off += n
    total = off
    if total == 0:
        raise ValueError("cannot bucket an empty pytree")
    target = max(1, int(bucket_mb * (1 << 20) / 4))
    bucket_elems = -(-target // multiple_of) * multiple_of
    # never more padding than one bucket: shrink to the padded total
    bucket_elems = min(bucket_elems,
                       -(-total // multiple_of) * multiple_of)
    num_buckets = -(-total // bucket_elems)
    return BucketLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                        offsets=tuple(offsets), sizes=sizes, total=total,
                        bucket_elems=bucket_elems, num_buckets=num_buckets)


def pack_buckets(tree: Any, layout: BucketLayout) -> jnp.ndarray:
    """Pytree -> (num_buckets, bucket_elems) f32 bucket stack."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(layout.sizes):
        raise ValueError(
            f"tree has {len(leaves)} leaves, layout expects "
            f"{len(layout.sizes)}")
    flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])
    if flat.shape[0] != layout.total:
        raise ValueError(
            f"tree holds {flat.shape[0]} elements, layout expects "
            f"{layout.total}")
    flat = compat.pad_trailing(flat, layout.padded_total - layout.total)
    return flat.reshape(layout.num_buckets, layout.bucket_elems)


def unpack_buckets(buckets: jnp.ndarray, layout: BucketLayout) -> Any:
    """(num_buckets, bucket_elems) -> pytree with original dtypes."""
    flat = buckets.reshape(-1)
    leaves = [
        flat[off:off + n].reshape(shape).astype(dtype)
        for off, n, shape, dtype in zip(layout.offsets, layout.sizes,
                                        layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def init_error_buckets(layout: BucketLayout) -> jnp.ndarray:
    """Per-rank flat error-feedback state (one rank's slice)."""
    return jnp.zeros((layout.num_buckets, layout.bucket_elems),
                     jnp.float32)


# --------------------------------------------------------------------------
# the exchange schedule
# --------------------------------------------------------------------------


def exchange_buckets(
    buckets: jnp.ndarray,
    err: Optional[jnp.ndarray] = None,
    *,
    axis: compat.AxisNames,
    axis_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Inside shard_map(manual over ``axis``): all-reduce the stack.

    ``buckets``: (num_buckets, bucket_elems) — this rank's gradient
    contribution, packed. ``err``: same shape, this rank's persistent
    error-feedback state (compressed mode only). Returns the globally
    summed stack and the new error state.

    Exactly two collectives cross the link regardless of bucket or leaf
    count; compressed mode keeps int8 (+bit-cast scales) on the wire in
    both directions.
    """
    nb, be = buckets.shape
    p = axis_size
    if be % p:
        raise ValueError(f"bucket_elems {be} not divisible by axis size "
                         f"{p}; build the layout with multiple_of={p}")
    shard = be // p
    x = buckets.reshape(nb, p, shard)

    if not compress:
        sh = jax.lax.psum_scatter(x, axis, scatter_dimension=1,
                                  tiled=False)              # (nb, shard)
        onehot = (None if compat.NATIVE_MANUAL_COLLECTIVES
                  else compat.manual_axis_onehot(axis, p, tie=buckets))
        full = compat.manual_all_gather(sh, axis, p, onehot)
        return jnp.moveaxis(full, 0, 1).reshape(nb, be), err

    if shard % block_size:
        raise ValueError(
            f"shard {shard} not divisible by block_size {block_size}; "
            f"build the layout with multiple_of={p * block_size}")
    ns = shard // block_size

    want_err = err is not None
    corrected = x + (err.reshape(nb, p, shard) if want_err else 0.0)
    # collective-free on native jax (axis_index); one tiny identity
    # scatter on the emulated stack
    onehot = compat.manual_axis_onehot(axis, p, tie=buckets)
    if key is not None:
        # decorrelate stochastic rounding across ranks
        key = jax.random.fold_in(key, jnp.argmax(onehot).astype(jnp.int32))

    # ONE fused quantize over the whole concatenated bucket stack
    q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key,
                               impl=impl, interpret=interpret)
    # q: (nb*p*ns, block), s: (nb*p*ns,)
    if want_err:
        deq_local = (q.astype(jnp.float32) *
                     s[:, None]).reshape(nb, p, shard)
        new_err = corrected - deq_local      # stage-1 residual, all shards

    payload = compression.fuse_payload(
        q.reshape(nb, p, ns, block_size), s.reshape(nb, p, ns))
    # rank-major leading axis for the exchange: row j = message to rank j
    wire = jnp.moveaxis(payload, 1, 0)       # (p, nb, ns, block+4)
    rx = compat.manual_all_to_all(wire, axis, p, onehot)  # row j = from j
    q_x, s_x = compression.split_payload(rx, block_size)

    # fused dequant-accumulate over the peer axis (receive side)
    shard_sum = q_ops.dequant_accum(
        q_x.reshape(p, nb * ns, block_size), s_x.reshape(p, nb * ns),
        impl=impl, interpret=interpret)      # (nb*ns, block)

    # re-quantize the summed shard for the broadcast leg
    q2, s2 = q_ops.quantize_int8(shard_sum, block_size=block_size,
                                 key=None, impl=impl, interpret=interpret)
    if want_err:
        deq2 = (q2.astype(jnp.float32) * s2[:, None]).reshape(nb, shard)
        resid2 = shard_sum.reshape(nb, shard) - deq2
        # stage-2 residual belongs to this shard's owner (= this rank):
        # scatter it into our slot of the flat error state
        new_err = new_err + resid2[:, None, :] * onehot[None, :, None]

    payload2 = compression.fuse_payload(
        q2.reshape(nb, ns, block_size), s2.reshape(nb, ns))
    g2 = compat.manual_all_gather(payload2, axis, p, onehot)
    qg, sg = compression.split_payload(g2, block_size)
    full = qg.astype(jnp.float32) * sg[..., None]      # (p, nb, ns, B)
    full = jnp.moveaxis(full, 0, 1).reshape(nb, be)
    return full, (new_err.reshape(nb, be) if want_err else None)


# --------------------------------------------------------------------------
# analytic link-byte model (for §Roofline and the reduction benchmark)
# --------------------------------------------------------------------------


def modeled_link_bytes(layout: BucketLayout, ranks: int, *,
                       compress: bool = False,
                       block_size: int = 256) -> int:
    """Per-rank bytes on the reduction link for one bucketed exchange.

    Uncompressed: reduce-scatter + all-gather each move (p-1)/p of the
    padded buffer per rank. Compressed: the all_to_all sends (p-1)/p of
    the fused int8 payload, the all-gather broadcast leg forwards
    (p-1) shard payloads. This models the *native* schedule; the
    psum-based CPU emulation in compat.py moves more bytes but issues
    the same number of collectives.
    """
    p = ranks
    n = layout.padded_total
    if not compress:
        return int(2 * (p - 1) / p * n * 4)
    blocks = n // block_size
    payload = n + blocks * 4                   # int8 values + fused scales
    a2a = (p - 1) / p * payload
    ag = (p - 1) / p * payload                 # p shard payloads, ring leg
    return int(a2a + ag)


def modeled_per_leaf_bytes(tree: Any, ranks: int, *,
                           compress: bool = False,
                           block_size: int = 256) -> int:
    """Per-rank link bytes for the legacy per-leaf schedule.

    Uncompressed: one psum per leaf (ring all-reduce, ~2(p-1)/p of the
    leaf). Compressed (legacy _cross_pod_reduce): all-gather of EVERY
    rank's full quantized payload — (p-1) full payloads per rank, the
    O(ranks) receive-bandwidth term the bucketed schedule removes.
    """
    p = ranks
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        if not compress:
            total += int(2 * (p - 1) / p * n * 4)
        else:
            blocks = -(-n // block_size)
            payload = blocks * block_size + blocks * 4
            total += int((p - 1) * payload)
    return total
