"""Deterministic fault injection for live heterogeneity.

The paper's capacity table is static; real heterogeneous fleets drift.
This module scripts that drift as a declarative, seedable *schedule* of
per-rank faults and turns it into the signals the rest of the stack
already consumes:

  * ``slowdown(rank, factor, start, duration)`` — thermal throttling /
    shared tenancy: the rank's modeled step time is multiplied by
    ``factor`` while the window is active.
  * ``kill(rank=..|pod=.., step=..)`` — dead rank or whole-pod loss:
    the victim stops reporting step times from ``step`` on (the
    straggler monitor times it out, soft-replans it to zero rows, or
    escalates ``RemeshRequired`` when the survivors cannot fit the
    global batch).
  * ``flaky(rank, drop_prob, start, duration)`` — a missed step-time
    *report* (monitoring-plane noise, not lost work): with probability
    ``drop_prob`` the rank reports ``None`` for that step.
  * ``ckpt_io_fail(step=.., mode=.., fails=..)`` — transient (or
    persistent) ``OSError`` injected into the checkpoint writer via
    ``ChaosEngine.ckpt_fault_hook`` (exercises the writer's bounded
    retry; ``step=None`` targets every save).

Everything is a pure function of (schedule, seed, step, rank): the
modeled trace replays bit-identically from the seed — flaky drops are
hashed from ``SeedSequence([seed, step, rank])``, never from call
order — so a chaos run is a *reproducible* regression scenario, not a
flaky test.

Timing model (single-process emulation gives every rank the same host
clock, so this is where per-rank differentiation comes from):

  t_r(step) = measured * (n_r / speed_r) / mean_alive(n / speed)
            * slowdown_factor_r(step)

``speed_r`` is the rank's declared relative capacity (the "true"
hardware speed the chaos engine perturbs); the normalization keeps the
mean modeled time equal to the measured host step time. At the replan
fixed point (rows proportional to speed/factor) every rank reports the
same time — the monitor's throughput feed converges instead of
oscillating, and a sustained slowdown settles at rows ∝ 1/factor.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("slowdown", "kill", "flaky", "ckpt_io_fail")
CKPT_FAIL_MODES = ("transient", "persistent")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative fault. Use the module-level constructors
    (:func:`slowdown`, :func:`kill`, :func:`flaky`,
    :func:`ckpt_io_fail`) rather than building these by hand."""

    kind: str
    rank: Optional[int] = None     # slowdown / flaky / kill target
    pod: Optional[int] = None      # kill target (whole pod)
    factor: float = 1.0            # slowdown multiplier (> 1 = slower)
    start: int = 0                 # first affected step (inclusive)
    duration: Optional[int] = None  # steps; None = until the run ends
    drop_prob: float = 0.0         # flaky: P(missed report) per step
    step: Optional[int] = None     # kill / ckpt_io_fail trigger step
    mode: str = "transient"        # ckpt_io_fail: transient|persistent
    fails: int = 2                 # ckpt_io_fail transient: attempts

    def validate(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"valid: {FAULT_KINDS}")
        if self.kind == "slowdown":
            if self.rank is None or self.factor <= 0:
                raise ValueError("slowdown needs rank and factor > 0")
        elif self.kind == "kill":
            if (self.rank is None) == (self.pod is None):
                raise ValueError("kill needs exactly one of rank | pod")
            if self.step is None:
                raise ValueError("kill needs step")
        elif self.kind == "flaky":
            if self.rank is None or not 0.0 <= self.drop_prob <= 1.0:
                raise ValueError("flaky needs rank and drop_prob in "
                                 "[0, 1]")
        elif self.kind == "ckpt_io_fail":
            if self.mode not in CKPT_FAIL_MODES:
                raise ValueError(f"ckpt_io_fail mode {self.mode!r}; "
                                 f"valid: {CKPT_FAIL_MODES}")
            if self.fails < 1:
                raise ValueError("ckpt_io_fail needs fails >= 1")

    def active(self, step: int) -> bool:
        """Whether a windowed fault (slowdown/flaky) covers ``step``."""
        if step < self.start:
            return False
        return self.duration is None or step < self.start + self.duration


def slowdown(rank: int, factor: float, start: int = 0,
             duration: Optional[int] = None) -> Fault:
    return Fault("slowdown", rank=rank, factor=factor, start=start,
                 duration=duration)


def kill(rank: Optional[int] = None, pod: Optional[int] = None,
         step: int = 0) -> Fault:
    return Fault("kill", rank=rank, pod=pod, step=step)


def flaky(rank: int, drop_prob: float, start: int = 0,
          duration: Optional[int] = None) -> Fault:
    return Fault("flaky", rank=rank, drop_prob=drop_prob, start=start,
                 duration=duration)


def ckpt_io_fail(step: Optional[int] = None, mode: str = "transient",
                 fails: int = 2) -> Fault:
    return Fault("ckpt_io_fail", step=step, mode=mode, fails=fails)


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A seedable set of faults. JSON form::

        {"seed": 0, "events": [
          {"kind": "slowdown", "rank": 1, "factor": 3.0,
           "start": 5, "duration": 20},
          {"kind": "kill", "pod": 1, "step": 40}]}
    """

    events: Tuple[Fault, ...] = ()
    seed: int = 0

    def validate(self) -> None:
        for ev in self.events:
            ev.validate()

    def with_events(self, *extra: Fault) -> "ChaosSchedule":
        return dataclasses.replace(self, events=self.events + extra)

    def to_record(self) -> Dict:
        events = []
        for ev in self.events:
            d = {k: v for k, v in dataclasses.asdict(ev).items()
                 if v is not None}
            events.append(d)
        return {"seed": int(self.seed), "events": events}

    @classmethod
    def from_record(cls, record: Dict) -> "ChaosSchedule":
        events = []
        for d in record.get("events", ()):
            known = {f.name for f in dataclasses.fields(Fault)}
            bad = set(d) - known
            if bad:
                raise ValueError(f"unknown fault field(s) {sorted(bad)} "
                                 f"in {d}")
            events.append(Fault(**d))
        sched = cls(events=tuple(events),
                    seed=int(record.get("seed", 0)))
        sched.validate()
        return sched

    def to_json(self) -> str:
        return json.dumps(self.to_record(), indent=1)

    @classmethod
    def from_json(cls, text: str) -> "ChaosSchedule":
        return cls.from_record(json.loads(text))


# ---- presets --------------------------------------------------------------
# Parameterized by the topology (num_ranks, data_per_pod) and run
# length so `--chaos <preset>` works on any mesh. Names are documented
# in the README chaos table and pinned by tests/test_config_docs.py.


def _preset_slowdown(num_ranks, data_per_pod, total_steps):
    victim = 1 % num_ranks
    return (slowdown(victim, factor=4.0,
                     start=max(total_steps // 5, 1)),)


def _preset_dead_rank(num_ranks, data_per_pod, total_steps):
    return (kill(rank=num_ranks - 1, step=max(total_steps // 3, 1)),)


def _preset_pod_kill(num_ranks, data_per_pod, total_steps):
    pods = max(num_ranks // max(data_per_pod, 1), 1)
    return (kill(pod=pods - 1, step=max(total_steps // 2, 1)),)


def _preset_storm(num_ranks, data_per_pod, total_steps):
    pods = max(num_ranks // max(data_per_pod, 1), 1)
    return (slowdown(1 % num_ranks, factor=3.0,
                     start=max(total_steps // 6, 1)),
            flaky(0, drop_prob=0.2, start=0,
                  duration=max(total_steps // 2, 1)),
            kill(pod=pods - 1, step=max(2 * total_steps // 3, 1)),
            ckpt_io_fail(step=None, mode="transient", fails=1))


PRESETS: Dict[str, Callable[[int, int, int], Tuple[Fault, ...]]] = {
    "slowdown": _preset_slowdown,
    "dead-rank": _preset_dead_rank,
    "pod-kill": _preset_pod_kill,
    "storm": _preset_storm,
}


def load_schedule(spec: str, num_ranks: int, data_per_pod: int = 1,
                  total_steps: int = 100, seed: int = 0
                  ) -> ChaosSchedule:
    """Resolve a ``--chaos`` value: a preset name or a schedule.json
    path. Presets are built for THIS topology and run length."""
    if spec in PRESETS:
        sched = ChaosSchedule(
            events=PRESETS[spec](num_ranks, data_per_pod, total_steps),
            seed=seed)
        sched.validate()
        return sched
    if os.path.exists(spec) or spec.endswith(".json"):
        with open(spec) as fh:
            return ChaosSchedule.from_json(fh.read())
    raise ValueError(f"--chaos {spec!r} is neither a schedule.json "
                     f"path nor a preset ({sorted(PRESETS)})")


# ---- engine ---------------------------------------------------------------


class ChaosEngine:
    """Applies a :class:`ChaosSchedule` to a concrete topology.

    Pure per-step queries (``slowdown_factor``, ``killed``,
    ``dropped``) plus the two integration surfaces:
    :meth:`step_times` (feeds ``StragglerMonitor.observe``) and
    :meth:`ckpt_fault_hook` (plugs into ``CheckpointManager``).
    """

    def __init__(self, schedule: ChaosSchedule, num_ranks: int,
                 data_per_pod: int = 1,
                 speeds: Optional[Sequence[float]] = None):
        schedule.validate()
        self.schedule = schedule
        self.num_ranks = int(num_ranks)
        self.data_per_pod = max(int(data_per_pod), 1)
        self.pods = max(self.num_ranks // self.data_per_pod, 1)
        if speeds is None:
            sp = np.ones(self.num_ranks, np.float64)
        else:
            sp = np.asarray(speeds, np.float64)
            if sp.shape != (self.num_ranks,):
                raise ValueError(f"speeds needs {self.num_ranks} "
                                 f"entries, got {sp.shape}")
            # capacity 0 declares a rank drained (0 rows), not
            # infinitely slow — model it at unit speed
            sp = np.where(sp > 0, sp, 1.0)
        self.speeds = sp
        for ev in schedule.events:
            if ev.rank is not None and not 0 <= ev.rank < self.num_ranks:
                raise ValueError(f"fault rank {ev.rank} out of range: "
                                 f"{self.num_ranks} DP rank(s)")
            if ev.pod is not None and not 0 <= ev.pod < self.pods:
                raise ValueError(f"fault pod {ev.pod} out of range: "
                                 f"mesh has {self.pods} pod(s)")

    # ---- per-(step, rank) queries ----------------------------------------

    def _pod(self, rank: int) -> int:
        return rank // self.data_per_pod

    def slowdown_factor(self, step: int, rank: int) -> float:
        f = 1.0
        for ev in self.schedule.events:
            if ev.kind == "slowdown" and ev.rank == rank \
                    and ev.active(step):
                f *= ev.factor
        return f

    def killed(self, step: int, rank: int) -> bool:
        for ev in self.schedule.events:
            if ev.kind != "kill" or step < ev.step:
                continue
            if ev.rank == rank or (ev.pod is not None
                                   and ev.pod == self._pod(rank)):
                return True
        return False

    def dropped(self, step: int, rank: int) -> bool:
        """Flaky missed report — deterministic in (seed, step, rank)."""
        for ev in self.schedule.events:
            if ev.kind != "flaky" or ev.rank != rank \
                    or not ev.active(step):
                continue
            u = np.random.default_rng(np.random.SeedSequence(
                [self.schedule.seed, step, rank])).random()
            if u < ev.drop_prob:
                return True
        return False

    # ---- integration surfaces --------------------------------------------

    def step_times(self, step: int, rows_per_rank: Sequence[int],
                   measured: float) -> List[Optional[float]]:
        """Modeled per-rank step times for ``StragglerMonitor.observe``.

        ``measured`` is the host-clock step time; ``None`` entries are
        killed ranks (dead — no report ever again) and flaky drops
        (this step's report lost).
        """
        rows = np.maximum(np.asarray(rows_per_rank, np.float64), 1.0)
        load = rows / self.speeds                 # per-rank relative work
        norm = measured / float(load.mean())
        out: List[Optional[float]] = []
        for r in range(self.num_ranks):
            if self.killed(step, r) or self.dropped(step, r):
                out.append(None)
            else:
                out.append(norm * load[r] * self.slowdown_factor(step, r))
        return out

    def modeled_step_wall(self, step: int,
                          rows_per_rank: Sequence[int],
                          row_cost: float = 1.0) -> float:
        """Modeled wall-clock of one synchronous step: the max over
        alive ranks of (rows / speed) * slowdown * row_cost. Killed
        ranks drop out (their buffers are all-dummy after the replan;
        before it, their lost work shows up as training-progress loss,
        not wall time). Flaky drops are monitoring noise — the rank
        still does its work."""
        rows = np.maximum(np.asarray(rows_per_rank, np.float64), 1.0)
        load = rows / self.speeds
        wall = 0.0
        for r in range(self.num_ranks):
            if self.killed(step, r):
                continue
            wall = max(wall,
                       row_cost * load[r] * self.slowdown_factor(step, r))
        return wall

    def trace(self, num_steps: int, rows_per_rank: Sequence[int],
              measured: float = 1.0) -> List[Dict]:
        """The full modeled trace — pure function of (schedule, seed,
        topology): two engines built alike produce byte-identical JSON.
        """
        out = []
        for s in range(num_steps):
            out.append({
                "step": s,
                "times": self.step_times(s, rows_per_rank, measured),
                "wall": self.modeled_step_wall(s, rows_per_rank),
            })
        return out

    def ckpt_fault_hook(self) -> Callable[[int, str], None]:
        """A ``CheckpointManager.fault_hook``: raises ``OSError`` for
        scheduled ``ckpt_io_fail`` events. Transient events fail the
        first ``fails`` write attempts of a matching step, then let the
        retry succeed; persistent events fail every attempt."""
        attempts: Dict[Tuple[int, int], int] = {}

        def hook(step: int, path: str) -> None:
            for i, ev in enumerate(self.schedule.events):
                if ev.kind != "ckpt_io_fail":
                    continue
                if ev.step is not None and ev.step != step:
                    continue
                n = attempts.get((i, step), 0)
                attempts[(i, step)] = n + 1
                if ev.mode == "persistent" or n < ev.fails:
                    raise OSError(
                        f"chaos: injected ckpt_io_fail "
                        f"({ev.mode}, attempt {n + 1}) at step {step}")
        return hook

    def after_remesh(self, alive_pods: Sequence[int]) -> "ChaosEngine":
        """The engine for the surviving topology: ranks renumbered to
        the new (smaller) mesh, faults on dead pods dropped, global
        faults (``ckpt_io_fail``) kept. The seed is unchanged — the
        surviving ranks' flaky draws change with their new rank ids,
        which mirrors reality (the re-meshed fleet is a new run)."""
        alive = sorted(set(alive_pods))
        pod_map = {p: i for i, p in enumerate(alive)}

        def map_rank(rank: int) -> Optional[int]:
            p = self._pod(rank)
            if p not in pod_map:
                return None
            return (pod_map[p] * self.data_per_pod
                    + rank % self.data_per_pod)

        events = []
        for ev in self.schedule.events:
            if ev.kind == "ckpt_io_fail":
                events.append(ev)
                continue
            if ev.pod is not None:
                if ev.pod in pod_map:
                    events.append(dataclasses.replace(
                        ev, pod=pod_map[ev.pod]))
                continue
            new_rank = map_rank(ev.rank)
            if new_rank is not None:
                events.append(dataclasses.replace(ev, rank=new_rank))
        speeds = np.concatenate([
            self.speeds[p * self.data_per_pod:(p + 1) * self.data_per_pod]
            for p in alive])
        return ChaosEngine(
            dataclasses.replace(self.schedule, events=tuple(events)),
            num_ranks=len(alive) * self.data_per_pod,
            data_per_pod=self.data_per_pod, speeds=speeds)
