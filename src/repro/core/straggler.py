"""Straggler mitigation: per-rank step-time EMA -> capacity replanning.

The paper sets per-node batch sizes statically from memory capacity.
Real heterogeneous fleets drift (thermal throttling, shared tenancy,
failing HBM): we track an EMA of each DP rank's step time and, every
``replan_interval`` steps, re-run the capacity planner with measured
throughput (rows/sec) as the capacity score — slow ranks shed real rows
to fast ranks; the weighted aggregation keeps the math exact through any
replan. A rank that stops reporting (timeout) is treated as dead:
capacity 0, all-dummy buffer, zero weight — training continues without
it until the elastic controller re-meshes (elastic.py).

Host-side logic (numpy): runs between steps, outside jit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.capacity import CapacityPlan, plan_capacities


class RemeshRequired(RuntimeError):
    """Soft replanning cannot absorb the change with fixed SPMD shapes
    (e.g. the surviving buffers no longer fit the global batch) —
    escalate to the elastic controller (elastic.py, checkpoint restart).
    """


@dataclasses.dataclass
class StragglerMonitor:
    num_ranks: int
    ema_decay: float = 0.9
    replan_interval: int = 100
    dead_timeout_steps: int = 3
    _ema: Optional[np.ndarray] = None
    _missed: Optional[np.ndarray] = None
    _steps: int = 0
    _dead_handled: frozenset = frozenset()

    def __post_init__(self):
        self._ema = np.zeros(self.num_ranks, np.float64)
        self._missed = np.zeros(self.num_ranks, np.int64)

    @property
    def step_time_ema(self) -> np.ndarray:
        return self._ema.copy()

    def observe(self, step_times: Sequence[Optional[float]]) -> None:
        """Record one step's per-rank times; None = no report (missed)."""
        if len(step_times) != self.num_ranks:
            raise ValueError(
                f"observe() got {len(step_times)} step times for "
                f"{self.num_ranks} ranks — after an elastic re-mesh the "
                f"monitor must be recreated for the new mesh width")
        self._steps += 1
        for r, t in enumerate(step_times):
            if t is None:
                self._missed[r] += 1
                continue
            self._missed[r] = 0
            if self._ema[r] == 0.0:
                self._ema[r] = t
            else:
                self._ema[r] = (self.ema_decay * self._ema[r] +
                                (1.0 - self.ema_decay) * t)

    def dead_ranks(self) -> np.ndarray:
        return np.flatnonzero(self._missed >= self.dead_timeout_steps)

    def should_replan(self) -> bool:
        """Window boundary — or IMMEDIATELY on a newly-dead rank: a rank
        dying at step ``k*interval + 1`` must not drag all-dummy steps
        for the rest of the window."""
        if set(self.dead_ranks().tolist()) - self._dead_handled:
            return True
        return self._steps > 0 and self._steps % self.replan_interval == 0

    def replan(self, plan: CapacityPlan) -> CapacityPlan:
        """New plan from measured throughput; dead ranks get capacity 0.

        Raises :class:`RemeshRequired` when the global batch no longer
        fits the surviving fixed-size buffers — the caller must escalate
        to elastic.plan_remesh (checkpoint restart with a new mesh).
        """
        self._dead_handled = frozenset(self.dead_ranks().tolist())
        rows = np.maximum(plan.rows_per_rank.astype(np.float64), 1.0)
        ema = np.where(self._ema > 0, self._ema, np.inf)
        throughput = np.where(np.isfinite(ema), rows / ema, 0.0)
        if not throughput.any():
            throughput = np.ones(self.num_ranks)
        throughput[self.dead_ranks()] = 0.0
        try:
            return plan_capacities(plan.global_rows, throughput,
                                   buffer_rows=plan.buffer_rows)
        except ValueError as e:
            raise RemeshRequired(str(e)) from e
