"""HetSeq core: heterogeneous-capacity data parallelism, SPMD-native.

The paper's mechanisms:
  weighting.py   M1  weighted loss/grad aggregation
  capacity.py    M2  per-rank capacity model + planner
  dummy.py       M3  dummy/partial batch construction (weight masks)
  accumulate.py  M4  delayed update with exact heterogeneous weighting

Beyond-paper (required at 1000+ node scale):
  compression.py   int8 gradient compression + error feedback (DCN leg)
  hierarchical.py  ICI reduce-scatter -> DCN all-reduce -> ICI all-gather
  straggler.py     step-time EMA -> capacity replanning
  elastic.py       re-mesh on membership change, exact resume
"""
