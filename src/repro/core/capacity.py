"""M2 — capacity model and planner for heterogeneous DP ranks.

The paper sets per-GPU batch sizes / max-tokens statically according to
each node's memory. On TPU we model capacity per DP rank (pod x data
position): ``capacities`` are relative throughput/memory scores. SPMD
requires uniform buffer shapes, so the planner fills each rank's
fixed-size buffer with ``n_i <= buffer_rows`` real rows (proportional to
capacity, largest-remainder rounding) and dummy rows (weight 0) for the
rest — the paper's partial/empty-batch mechanism (M3) promoted to the
core scheduling primitive.

The planner is host-side NumPy (it runs between steps, never in the jit
path) and is re-invoked by the straggler monitor (replanning) and the
elastic controller (rank loss => capacity 0 => all-dummy rank).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Assignment of real rows to DP ranks for one plan window."""

    capacities: np.ndarray        # (R,) relative capacity scores
    rows_per_rank: np.ndarray     # (R,) real rows n_i assigned per rank
    buffer_rows: int              # uniform per-rank buffer (>= max n_i)
    global_rows: int              # sum(rows_per_rank)

    @property
    def num_ranks(self) -> int:
        return len(self.rows_per_rank)

    @property
    def padded_rows(self) -> int:
        return self.num_ranks * self.buffer_rows

    def row_weights(self) -> np.ndarray:
        """(R, buffer_rows) 1.0 for real rows, 0.0 for dummy rows."""
        w = np.zeros((self.num_ranks, self.buffer_rows), np.float32)
        for r, n in enumerate(self.rows_per_rank):
            w[r, :n] = 1.0
        return w

    def efficiency(self) -> float:
        """Fraction of buffer slots holding real rows (1.0 = homogeneous)."""
        return float(self.global_rows) / float(self.padded_rows)


def plan_record(plan: CapacityPlan) -> dict:
    """Structured JSON-able form of a plan (checkpoint meta.json).

    The checkpoint layer refuses to stringify plans (a str round-trips
    to nothing); this record round-trips through
    :func:`plan_from_record` into a real, usable :class:`CapacityPlan`.
    """
    return {
        "capacities": [float(c) for c in plan.capacities],
        "rows_per_rank": [int(r) for r in plan.rows_per_rank],
        "buffer_rows": int(plan.buffer_rows),
        "global_rows": int(plan.global_rows),
    }


def plan_from_record(record: dict) -> CapacityPlan:
    return CapacityPlan(
        capacities=np.asarray(record["capacities"], np.float32),
        rows_per_rank=np.asarray(record["rows_per_rank"], np.int64),
        buffer_rows=int(record["buffer_rows"]),
        global_rows=int(record["global_rows"]))


def plan_capacities(
    global_rows: int,
    capacities: Sequence[float],
    buffer_rows: Optional[int] = None,
    min_rows: int = 0,
    headroom: float = 1.0,
    round_buffer_to: int = 1,
) -> CapacityPlan:
    """Largest-remainder proportional allocation of rows to ranks.

    ``buffer_rows`` defaults to the smallest uniform buffer that fits the
    allocation (ceil of the max share), scaled by ``headroom`` (> 1.0
    reserves dummy slots so later replans can shift load without a
    shape change / recompile). Dead ranks (capacity 0) get 0 rows and an
    all-dummy buffer — collectives still fire uniformly.
    """
    caps = np.asarray(capacities, np.float64)
    if caps.ndim != 1 or len(caps) == 0:
        raise ValueError("capacities must be a non-empty 1-D sequence")
    if np.any(caps < 0):
        raise ValueError("capacities must be >= 0")
    total = caps.sum()
    if total <= 0:
        raise ValueError("at least one rank must have capacity > 0")

    share = global_rows * caps / total
    base = np.floor(share).astype(np.int64)
    rem = global_rows - int(base.sum())
    # hand the leftover rows to the largest fractional remainders
    frac_order = np.argsort(-(share - base), kind="stable")
    base[frac_order[:rem]] += 1
    base = np.maximum(base, np.where(caps > 0, min_rows, 0))
    # min_rows may have overshot: trim from the largest allocations
    excess = int(base.sum()) - global_rows
    if excess > 0:
        order = np.argsort(-base, kind="stable")
        for r in order:
            take = min(excess, int(base[r]) - min_rows)
            base[r] -= take
            excess -= take
            if excess == 0:
                break

    need = int(base.max())
    if buffer_rows is None:
        buffer_rows = int(np.ceil(need * headroom))
    if round_buffer_to > 1:          # microbatch divisibility (M4)
        buffer_rows = -(-buffer_rows // round_buffer_to) * round_buffer_to
    if need > buffer_rows:
        # capacity-constrained: clip and redistribute to ranks with room
        overflow = 0
        for r in range(len(base)):
            if base[r] > buffer_rows:
                overflow += int(base[r]) - buffer_rows
                base[r] = buffer_rows
        for r in np.argsort(-caps, kind="stable"):
            if overflow == 0:
                break
            room = buffer_rows - int(base[r]) if caps[r] > 0 else 0
            take = min(room, overflow)
            base[r] += take
            overflow -= take
        if overflow > 0:
            raise ValueError(
                f"global_rows={global_rows} exceeds total buffer capacity "
                f"{buffer_rows * int((caps > 0).sum())}")

    return CapacityPlan(capacities=caps.astype(np.float32),
                        rows_per_rank=base.astype(np.int64),
                        buffer_rows=int(buffer_rows),
                        global_rows=int(base.sum()))


def homogeneous_plan(global_rows: int, num_ranks: int,
                     headroom: float = 1.0) -> CapacityPlan:
    return plan_capacities(global_rows, np.ones(num_ranks),
                           headroom=headroom)


def replan_from_step_times(plan: CapacityPlan,
                           step_time_ema: np.ndarray) -> CapacityPlan:
    """Straggler feedback: capacity ∝ measured throughput (rows/sec).

    A rank processing its rows slowly gets proportionally fewer next
    window. Dead ranks (ema = inf) get capacity 0 (all-dummy) — inf is
    the ONLY sanctioned dead-rank marker. A finite measurement <= 0 or
    a NaN is not a slow rank, it is a broken monitor feeding the
    planner garbage; silently zeroing it would quietly starve a healthy
    rank, so those raise loudly naming the offending ranks.
    """
    ema = np.asarray(step_time_ema, np.float64)
    if ema.shape != (plan.num_ranks,):
        raise ValueError(
            f"step_time_ema has shape {ema.shape}, plan has "
            f"{plan.num_ranks} ranks")
    bad = np.nonzero(np.isnan(ema) | (np.isfinite(ema) & (ema <= 0)))[0]
    if bad.size:
        raise ValueError(
            f"measured step times must be positive (inf = dead rank); "
            f"ranks {bad.tolist()} reported "
            f"{ema[bad].tolist()} — a zero/negative/NaN step time is a "
            "broken measurement, not a fast rank")
    rows = np.maximum(plan.rows_per_rank.astype(np.float64), 1.0)
    with np.errstate(divide="ignore"):
        throughput = np.where(np.isfinite(ema), rows / ema, 0.0)
    if throughput.sum() <= 0:
        raise ValueError("all ranks dead")
    return plan_capacities(plan.global_rows, throughput,
                           buffer_rows=plan.buffer_rows)
