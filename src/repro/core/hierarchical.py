"""Beyond-paper: hierarchical (ICI-then-DCN) gradient reduction.

On a multi-pod mesh ("pod", "data", "model"), a flat all-reduce over
("pod","data") pushes full-gradient traffic over the slow cross-pod DCN
link. The hierarchical schedule:

  1. in-pod reduce-scatter over "data" (fast ICI) — each in-pod rank
     owns a 1/data_size shard of the pod-local gradient sum;
  2. cross-pod all-reduce of the *shard only* over "pod" (DCN) —
     optionally int8-compressed with error feedback (compression.py);
  3. in-pod all-gather over "data" to rebuild the full gradient.

Cross-pod bytes drop by data_size (16x) x compression (~3.9x) vs the
flat reduction. Expressed with shard_map(axis_names={"pod","data"})
so the "model" axis stays under automatic (pjit) partitioning.

Two granularities:
  * ``hierarchical_reduce_leaf`` / ``hierarchical_reduce_tree`` — the
    legacy per-leaf walk: one schedule instance per pytree leaf, so a
    transformer's dozens of leaves cost dozens of latency-bound DCN
    collectives per step.
  * ``hierarchical_reduce_bucketed`` — the flat-buffer engine
    (core/buckets.py): the whole tree is packed into fixed-size f32
    buckets first, then ONE reduce-scatter, ONE cross-pod exchange and
    ONE gather move the entire stack. This is the hot-path variant;
    the reduce-scatter over "data" runs before the pack-side quantize,
    so only 1/data_size of the buffer exists per rank when the DCN leg
    fires.
  * ``hierarchical_reduce_bucketed_overlapped`` — the same 3-level
    schedule as a double-buffered per-bucket pipeline: bucket k+1's
    in-pod reduce-scatter + quantize run while bucket k's DCN exchange
    is in flight (2 DCN collectives per bucket instead of 2 total —
    the latency/overlap trade benchmarks/overlap_bench.py models).

This module provides the *manual-collective* building blocks for the
fully-manual ({pod, data}) mesh regions used by the distributed tests
and benchmarks. The train step (launch/steps.py) runs a partially-
manual variant of the same schedule: manual over "pod" only, with the
in-pod legs left to XLA's automatic ("data"-FSDP) partitioning — its
``HetConfig.overlap`` path therefore pipelines the flat engine
(core/buckets.py) over the pod axis rather than calling the 3-level
functions here (wiring the fully-manual 3-level pipeline into the step
is an open ROADMAP item: grad-of-scan cannot lower inside partially-
manual regions on the compat jaxlib).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import buckets as bkt
from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import ref as q_ref


def _pad_to(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    return compat.pad_trailing(flat, (-flat.shape[0]) % mult)


def hierarchical_reduce_leaf(
    g: jnp.ndarray,
    err: Optional[jnp.ndarray],
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    data_size: int,
    pod_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Inside shard_map(manual over {pod, data}): reduce one leaf.

    ``g`` is this rank's local gradient contribution (sum over its
    tokens). Returns (globally summed gradient, new error state).
    """
    shape = g.shape
    flat = _pad_to(g.astype(jnp.float32), data_size)
    # 1) in-pod reduce-scatter over ICI: each rank owns a shard
    shard = jax.lax.psum_scatter(
        flat.reshape(data_size, -1), data_axis, scatter_dimension=0,
        tiled=False)
    # 2) cross-pod reduction over DCN
    if compress:
        corrected = shard + (err if err is not None else 0.0)
        q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key)
        deq_local = q_ref.dequantize_int8(q, s, corrected.shape, block_size)
        new_err = corrected - deq_local
        # int8 payload + per-block scales cross the DCN link; the sum
        # is rebuilt from the per-pod (values, scales) pairs
        q_all = compat.manual_all_gather(q, pod_axis, pod_size)
        s_all = compat.manual_all_gather(s, pod_axis, pod_size)
        shard = jnp.einsum("pbk,pb->bk", q_all.astype(jnp.float32),
                           s_all).reshape(-1)[:shard.shape[0]]
    else:
        new_err = err
        shard = jax.lax.psum(shard, pod_axis)
    # 3) in-pod all-gather over ICI to rebuild the full leaf
    full = compat.manual_all_gather(shard, data_axis,
                                    data_size).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return full[:n].reshape(shape), new_err


def hierarchical_reduce_tree(
    grads: Any,
    err_state: Optional[Any],
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    data_size: int,
    pod_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
) -> Tuple[Any, Optional[Any]]:
    """LEGACY: apply hierarchical_reduce_leaf across a gradient pytree.

    One full schedule (and its DCN collectives) per leaf — prefer
    :func:`hierarchical_reduce_bucketed` on hot paths.
    """
    leaves, treedef = jax.tree.flatten(grads)
    errs = (treedef.flatten_up_to(err_state) if err_state is not None
            else [None] * len(leaves))
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    outs, nerrs = [], []
    for g, e, k in zip(leaves, errs, keys):
        o, ne = hierarchical_reduce_leaf(
            g, e, data_axis=data_axis, pod_axis=pod_axis,
            data_size=data_size, pod_size=pod_size,
            compress=compress, block_size=block_size, key=k)
        outs.append(o)
        nerrs.append(ne)
    new_err = (treedef.unflatten(nerrs) if err_state is not None else None)
    return treedef.unflatten(outs), new_err


def hierarchical_reduce_bucketed(
    grads: Any,
    err: Optional[jnp.ndarray],
    layout: bkt.BucketLayout,
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    data_size: int,
    pod_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference",
) -> Tuple[Any, Optional[jnp.ndarray]]:
    """Bucketed 3-level reduction, inside shard_map(manual={pod, data}).

    The whole pytree is packed into the (num_buckets, bucket_elems)
    stack, reduce-scattered over "data" in ONE collective, the
    1/data_size shard crosses the DCN link through the bucketed
    exchange (core/buckets.py — two collectives, int8 payload when
    ``compress``), and ONE in-pod gather rebuilds the stack. The error
    state ``err`` is this rank's flat
    (num_buckets, bucket_elems / data_size) slice.

    The layout must be built with
    ``multiple_of = data_size * pod_size * block_size``.
    """
    flat = bkt.pack_buckets(grads, layout)            # (nb, be)
    nb, be = flat.shape
    if be % data_size:
        raise ValueError(
            f"bucket_elems {be} not divisible by data_size {data_size}")
    # 1) in-pod reduce-scatter (ICI): one collective for the whole stack
    shard = jax.lax.psum_scatter(
        flat.reshape(nb, data_size, be // data_size), data_axis,
        scatter_dimension=1, tiled=False)             # (nb, be/data)
    # 2) cross-pod bucketed exchange (DCN)
    red, new_err = bkt.exchange_buckets(
        shard, err, axis=pod_axis, axis_size=pod_size,
        compress=compress, block_size=block_size, key=key, impl=impl)
    # 3) in-pod all-gather (ICI): rebuild the full stack
    full = compat.manual_all_gather(red, data_axis, data_size)
    flat = jnp.moveaxis(full, 0, 1).reshape(nb, be)
    return bkt.unpack_buckets(flat, layout), new_err


def hierarchical_reduce_bucketed_overlapped(
    grads: Any,
    err: Optional[jnp.ndarray],
    layout: bkt.BucketLayout,
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    data_size: int,
    pod_size: int,
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
    impl: str = "reference",
) -> Tuple[Any, Optional[jnp.ndarray]]:
    """Double-buffered 3-level pipeline, inside shard_map(manual={pod,
    data}).

    Per-bucket version of :func:`hierarchical_reduce_bucketed`: while
    bucket *k*'s cross-pod (DCN) exchange is in flight, bucket *k+1*
    runs its in-pod reduce-scatter + send-side quantize — the ICI legs
    and the quantize kernels hide behind the slow link exactly like the
    flat pipeline in core/buckets.py (whose per-bucket building blocks
    this reuses). ``err`` is this rank's flat
    (num_buckets, bucket_elems / data_size) slice.
    """
    flat = bkt.pack_buckets(grads, layout)              # (nb, be)
    nb, be = flat.shape
    if be % data_size:
        raise ValueError(
            f"bucket_elems {be} not divisible by data_size {data_size}")
    shard = be // data_size
    if shard % pod_size:
        raise ValueError(
            f"in-pod shard {shard} not divisible by pod_size {pod_size}")
    if compress and (shard // pod_size) % block_size:
        raise ValueError(
            f"per-pod shard {shard // pod_size} not divisible by "
            f"block_size {block_size}; build the layout with "
            f"multiple_of={data_size * pod_size * block_size}")
    want_err = compress and err is not None
    e = err.reshape(nb, pod_size, shard // pod_size) if want_err else None
    onehot = compat.manual_axis_onehot(pod_axis, pod_size, tie=flat)

    def prep(k, raw_k, err_k):
        # in-pod reduce-scatter (ICI) for bucket k, then the cross-pod
        # send-side leg — both overlap bucket k-1's DCN exchange
        sh = jax.lax.psum_scatter(
            raw_k.reshape(data_size, shard), data_axis,
            scatter_dimension=0, tiled=False)           # (shard,)
        bkey = key
        if compress and bkey is not None:
            bkey = jax.random.fold_in(bkey, k)
            bkey = jax.random.fold_in(
                bkey, jnp.argmax(onehot).astype(jnp.int32))
        return bkt.prepare_bucket(
            sh.reshape(pod_size, shard // pod_size), err_k,
            compress=compress, block_size=block_size, key=bkey,
            impl=impl, interpret=False)

    def exchange(prepared):
        payload, resid1 = prepared
        red_k, nerr_k = bkt.exchange_prepared_bucket(
            payload, resid1, axis=pod_axis, axis_size=pod_size,
            compress=compress, block_size=block_size, impl=impl,
            interpret=False, onehot=onehot)             # (shard,)
        # in-pod all-gather (ICI) rebuilds bucket k as it lands
        full = compat.manual_all_gather(red_k, data_axis, data_size)
        return full.reshape(be), nerr_k

    # shared driver: bucket k+1's ICI reduce-scatter + quantize (prep)
    # overlap bucket k's in-flight DCN exchange; the last bucket runs
    # in an epilogue so the prep's ICI reduce-scatter is never issued
    # for a dead (wrapped-around) bucket
    outs, nerrs, _ = bkt.run_overlapped_pipeline(
        nb, prep, exchange, raw=flat, err=e)
    new_err = nerrs.reshape(nb, shard) if want_err else None
    return bkt.unpack_buckets(outs, layout), new_err


def cross_pod_bytes(grads: Any, num_params_bytes: int = 4,
                    data_size: int = 16, compress: bool = False,
                    block_size: int = 256) -> int:
    """Analytic DCN bytes per step for the reduction (for §Roofline)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    shard = total // data_size
    if not compress:
        return shard * num_params_bytes * 2          # psum ~ 2x shard bytes
    payload = shard * 1 + -(-shard // block_size) * 4
    return payload * 2
