"""Beyond-paper: hierarchical (ICI-then-DCN) gradient reduction.

On a multi-pod mesh ("pod", "data", "model"), a flat all-reduce over
("pod","data") pushes full-gradient traffic over the slow cross-pod DCN
link. The hierarchical schedule:

  1. in-pod reduce-scatter over "data" (fast ICI) — each in-pod rank
     owns a 1/data_size shard of the pod-local gradient sum;
  2. cross-pod all-reduce of the *shard only* over "pod" (DCN) —
     optionally int8-compressed with error feedback (compression.py);
  3. in-pod all-gather over "data" to rebuild the full gradient.

Cross-pod bytes drop by data_size (16x) x compression (~3.9x) vs the
flat reduction. Expressed with jax.shard_map(axis_names={"pod","data"})
so the "model" axis stays under automatic (pjit) partitioning.

This module provides the *manual-collective* building block; the train
step (launch/steps.py) wires it behind ``HetConfig.grad_reduction``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import ref as q_ref


def _pad_to(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    return jnp.pad(flat, (0, pad)) if pad else flat


def hierarchical_reduce_leaf(
    g: jnp.ndarray,
    err: Optional[jnp.ndarray],
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Inside shard_map(manual over {pod, data}): reduce one leaf.

    ``g`` is this rank's local gradient contribution (sum over its
    tokens). Returns (globally summed gradient, new error state).
    """
    shape = g.shape
    data_size = jax.lax.axis_size(data_axis)
    flat = _pad_to(g.astype(jnp.float32), data_size)
    # 1) in-pod reduce-scatter over ICI: each rank owns a shard
    shard = jax.lax.psum_scatter(
        flat.reshape(data_size, -1), data_axis, scatter_dimension=0,
        tiled=False)
    # 2) cross-pod reduction over DCN
    if compress:
        corrected = shard + (err if err is not None else 0.0)
        q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key)
        deq_local = q_ref.dequantize_int8(q, s, corrected.shape, block_size)
        new_err = corrected - deq_local
        # int8 payload + fp32 scales cross the DCN link
        q_sum = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        s_all = jax.lax.all_gather(s, pod_axis)           # (pods, blocks)
        # reconstruct: sum of per-pod dequantized shards. int8 values were
        # summed pre-scale only if scales match; use per-pod scales via
        # the gathered table: deq_sum = Σ_p q_p * s_p. We recover it from
        # q_sum only when scales are shared — instead gather q too:
        # cheaper equivalent: psum of locally-dequantized shard would be
        # fp32 traffic; to keep int8 on the wire we gather int8 + scales.
        q_all = jax.lax.all_gather(q, pod_axis)           # (pods, blocks, B)
        del q_sum
        deq = jnp.einsum("pbk,pb->bk", q_all.astype(jnp.float32), s_all)
        shard = deq
    else:
        new_err = err
        shard = jax.lax.psum(shard, pod_axis)
    # 3) in-pod all-gather over ICI to rebuild the full leaf
    full = jax.lax.all_gather(shard, data_axis).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return full[:n].reshape(shape), new_err


def hierarchical_reduce_tree(
    grads: Any,
    err_state: Optional[Any],
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    compress: bool = False,
    block_size: int = 256,
    key: Optional[jax.Array] = None,
) -> Tuple[Any, Optional[Any]]:
    """Apply hierarchical_reduce_leaf across a gradient pytree."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = (treedef.flatten_up_to(err_state) if err_state is not None
            else [None] * len(leaves))
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    outs, nerrs = [], []
    for g, e, k in zip(leaves, errs, keys):
        o, ne = hierarchical_reduce_leaf(
            g, e, data_axis=data_axis, pod_axis=pod_axis,
            compress=compress, block_size=block_size, key=k)
        outs.append(o)
        nerrs.append(ne)
    new_err = (treedef.unflatten(nerrs) if err_state is not None else None)
    return treedef.unflatten(outs), new_err


def cross_pod_bytes(grads: Any, num_params_bytes: int = 4,
                    data_size: int = 16, compress: bool = False,
                    block_size: int = 256) -> int:
    """Analytic DCN bytes per step for the reduction (for §Roofline)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    shard = total // data_size
    if not compress:
        return shard * num_params_bytes * 2          # psum ~ 2x shard bytes
    payload = shard * 1 + -(-shard // block_size) * 4
    return payload * 2
