"""M4 — delayed update (gradient accumulation) with exact weighting.

The paper: aggregate losses from multiple forward passes before one
backward/update; under heterogeneity the microbatches have different
weights, so the accumulated update must divide by the *summed* weight
once — never average per-microbatch means.

Exactness: with per-microbatch objective sums O_i (differentiable) and
weight sums W_i,

    grad( (Σ O_i) / (Σ W_i) ) = (Σ grad O_i) / (Σ W_i)

so accumulating grad-of-sums and weights separately and dividing once is
*bit-identical* (up to fp reassociation) to one big batch — for any
capacity mix. This is the scan implemented here.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def scan_accumulate(
    grad_fn: Callable[[Any, Dict], Tuple[Tuple[jnp.ndarray, jnp.ndarray],
                                         Any]],
    params: Any,
    microbatches: Dict[str, jnp.ndarray],
    carry_dtype: Optional[Callable[[Any], Any]] = None,
) -> Tuple[Any, jnp.ndarray, jnp.ndarray]:
    """The shared accumulation scan core: UNSCALED sums.

    ``grad_fn(params, mb) -> ((obj_sum, weight_sum), grads)`` — i.e. a
    ``jax.value_and_grad(..., has_aux=True)`` of a (objective-sum,
    weight-sum) objective. Scans it over stacked microbatches and
    returns ``(grad_of_sums, obj_sum, weight_sum)`` WITHOUT the final
    division — the weighting math (divide by summed weight exactly
    once) lives in the callers: :func:`accumulate_grads` for the local
    path, launch/steps.py for the sharded train step (which divides
    after the cross-rank psum).

    ``carry_dtype``: per-leaf accumulator dtype policy (default fp32).
    """
    dtype_of = carry_dtype or (lambda p: jnp.float32)

    def body(carry, mb):
        g_acc, o_acc, w_acc = carry
        (o, w), g = grad_fn(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (g_acc, o_acc + o, w_acc + w), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype_of(p)), params)
    (g_sum, o_sum, w_sum), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), microbatches)
    return g_sum, o_sum, w_sum


def unrolled_accumulate(
    grad_fn: Callable[[Any, Dict], Tuple[Tuple[jnp.ndarray, jnp.ndarray],
                                         Any]],
    params: Any,
    microbatches: Dict[str, jnp.ndarray],
    carry_dtype: Optional[Callable[[Any], Any]] = None,
) -> Tuple[Any, jnp.ndarray, jnp.ndarray]:
    """``scan_accumulate`` as an unrolled python loop — same math, same
    add order, same carry dtypes, accum-times-larger HLO.

    Used when ``ModelConfig.scan_layers=False``: XLA compiles dots
    inside a scan body differently from top-level dots (last-bit fp
    differences), so the fully-unrolled program class — which the
    backward-overlap staged pipeline needs — keeps its accumulation
    unrolled too, making ``overlap="backward"`` bit-identical to the
    monolithic path at any ``accum_steps``.
    """
    dtype_of = carry_dtype or (lambda p: jnp.float32)
    accum = jax.tree.leaves(microbatches)[0].shape[0]
    g_acc = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype_of(p)), params)
    o_acc = jnp.zeros((), jnp.float32)
    w_acc = jnp.zeros((), jnp.float32)
    for i in range(accum):
        mb = jax.tree.map(lambda a: a[i], microbatches)
        (o, w), g = grad_fn(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        o_acc = o_acc + o
        w_acc = w_acc + w
    return g_acc, o_acc, w_acc


def accumulate_grads(
    loss_fn: Callable[..., Tuple[jnp.ndarray, jnp.ndarray, Dict]],
    params: Any,
    microbatches: Dict[str, jnp.ndarray],
    **loss_kwargs,
) -> Tuple[Any, jnp.ndarray, jnp.ndarray]:
    """Scan over stacked microbatches; returns (grads, loss, weight_sum).

    ``microbatches``: pytree of arrays with leading dim = accum steps.
    ``grads`` is the gradient of the weighted-mean loss over all real
    tokens in all microbatches (already divided by the summed weight).
    """
    def obj(p, mb):
        o, w, _ = loss_fn(p, mb, **loss_kwargs)
        return o, w

    grad_fn = jax.value_and_grad(obj, has_aux=True)
    g_sum, o_sum, w_sum = scan_accumulate(grad_fn, params, microbatches)
    w_safe = jnp.maximum(w_sum, 1e-9)
    grads = jax.tree.map(lambda g: (g / w_safe).astype(jnp.float32), g_sum)
    return grads, o_sum / w_safe, w_sum


def split_microbatches(batch: Dict[str, jnp.ndarray], accum_steps: int,
                       num_ranks: int = 1) -> Dict[str, jnp.ndarray]:
    """(R*B, ...) -> (accum, R*B/accum, ...), preserving rank locality.

    The batch layout is rank-major (capacity.py): splitting the leading
    dim must give every microbatch an equal slice of EVERY rank's buffer
    (else microbatches land on rank subsets and SPMD stalls):
    (R, B, ...) -> (R, accum, B/accum, ...) -> (accum, R * B/accum, ...).
    Requires buffer_rows % accum == 0; callers size buffers accordingly.
    """
    def split(a):
        n = a.shape[0]
        if n % (accum_steps * num_ranks):
            raise ValueError(
                f"rows {n} not divisible by accum {accum_steps} "
                f"x ranks {num_ranks}")
        b = n // num_ranks
        a = a.reshape(num_ranks, accum_steps, b // accum_steps,
                      *a.shape[1:])
        a = jnp.swapaxes(a, 0, 1)
        return a.reshape(accum_steps, num_ranks * (b // accum_steps),
                         *a.shape[3:])

    return {k: split(v) for k, v in batch.items()}
