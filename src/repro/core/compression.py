"""Beyond-paper: int8 gradient compression with error feedback.

Applied ONLY to the cross-pod ("pod" axis / DCN) leg of the gradient
reduction — the slow, heterogeneous link that is the TPU analogue of the
paper's campus Ethernet. In-pod (ICI) reductions stay full precision.

Scheme (per leaf, per step):
  1. e_corrected = grad + error_state           (error feedback)
  2. q, scales  = blockwise int8 quantize (kernels/quantize)
  3. exchange q + scales across pods (hierarchical.py does the collective)
  4. error_state' = e_corrected - dequant(q)    (what compression lost)

Error feedback makes the compressed reduction converge like the exact
one (Karimireddy et al. 2019); the quantizer's stochastic rounding keeps
single-step bias near zero as well.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import ref as q_ref


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray,
                  key: Optional[jax.Array] = None,
                  block_size: int = 256, impl: str = "reference"
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8 blocks, scales, new_error)."""
    corrected = g.astype(jnp.float32) + err
    q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key,
                               impl=impl)
    deq = q_ref.dequantize_int8(q, s, corrected.shape, block_size)
    return q, s, corrected - deq


def compress_tree(grads: Any, err_state: Any,
                  key: Optional[jax.Array] = None,
                  block_size: int = 256, impl: str = "reference"):
    """Quantize every leaf. Returns ((q_tree, s_tree), new_err_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_state)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    qs, ss, nes = [], [], []
    for g, e, k in zip(leaves, errs, keys):
        q, s, ne = compress_leaf(g, e, k, block_size, impl)
        qs.append(q)
        ss.append(s)
        nes.append(ne)
    return ((treedef.unflatten(qs), treedef.unflatten(ss)),
            treedef.unflatten(nes))


def decompress_tree(q_tree: Any, s_tree: Any, shapes: Any,
                    block_size: int = 256) -> Any:
    """Dequantize every leaf back to the original shapes pytree."""
    return jax.tree.map(
        lambda q, s, ref: q_ref.dequantize_int8(q, s, ref.shape, block_size),
        q_tree, s_tree, shapes)


def compression_ratio(grads: Any, block_size: int = 256) -> float:
    """Bytes(int8+scales) / bytes(fp32) for a gradient pytree."""
    fp = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + -(-g.size // block_size) * 4
               for g in jax.tree.leaves(grads))
    return comp / fp
