"""Beyond-paper: int8 gradient compression with error feedback.

Applied ONLY to the cross-pod ("pod" axis / DCN) leg of the gradient
reduction — the slow, heterogeneous link that is the TPU analogue of the
paper's campus Ethernet. In-pod (ICI) reductions stay full precision.

Scheme (per leaf or per bucket, per step):
  1. e_corrected = grad + error_state           (error feedback)
  2. q, scales  = blockwise int8 quantize (kernels/quantize)
  3. exchange q + scales across pods (hierarchical.py / buckets.py do
     the collective; the bucketed path fuses scales into the int8 wire
     payload via ``fuse_payload`` so each exchange is ONE collective)
  4. error_state' = e_corrected - dequant(q)    (what compression lost)

Error feedback makes the compressed reduction converge like the exact
one (Karimireddy et al. 2019); the quantizer's stochastic rounding keeps
single-step bias near zero as well.

The per-leaf ``compress_tree``/``decompress_tree`` walk below is the
legacy path (one quantize + one exchange per pytree leaf); the bucketed
flat-buffer engine in core/buckets.py quantizes whole bucket stacks in
a single kernel call and should be preferred on hot paths.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import ref as q_ref


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray,
                  key: Optional[jax.Array] = None,
                  block_size: int = 256, impl: str = "reference"
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8 blocks, scales, new_error)."""
    corrected = g.astype(jnp.float32) + err
    q, s = q_ops.quantize_int8(corrected, block_size=block_size, key=key,
                               impl=impl)
    deq = q_ref.dequantize_int8(q, s, corrected.shape, block_size)
    return q, s, corrected - deq


def compress_tree(grads: Any, err_state: Any,
                  key: Optional[jax.Array] = None,
                  block_size: int = 256, impl: str = "reference"):
    """Quantize every leaf. Returns ((q_tree, s_tree), new_err_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_state)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    qs, ss, nes = [], [], []
    for g, e, k in zip(leaves, errs, keys):
        q, s, ne = compress_leaf(g, e, k, block_size, impl)
        qs.append(q)
        ss.append(s)
        nes.append(ne)
    return ((treedef.unflatten(qs), treedef.unflatten(ss)),
            treedef.unflatten(nes))


def decompress_tree(q_tree: Any, s_tree: Any, shapes: Any,
                    block_size: int = 256) -> Any:
    """Dequantize every leaf back to the original shapes pytree."""
    return jax.tree.map(
        lambda q, s, ref: q_ref.dequantize_int8(q, s, ref.shape, block_size),
        q_tree, s_tree, shapes)


def fuse_payload(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Fuse int8 values + f32 scales into ONE wire buffer per block.

    ``q``: (..., blocks, block_size) int8, ``s``: (..., blocks) f32.
    On current jax this is an int8 buffer of block_size + 4 bytes per
    block — the scale bit-cast into 4 trailing bytes — so a compressed
    exchange is a single collective instead of one for values + one for
    scales. On old jaxlibs ``bitcast_convert_type`` is broken inside
    partially-manual regions AND the emulated collectives move f32
    anyway (compat.py), so the fused buffer is f32 with one trailing
    scale lane: identical collective structure and numerics, without
    the bit-packing.
    """
    from repro import compat

    if compat.NATIVE_MANUAL_COLLECTIVES:
        s_bytes = jax.lax.bitcast_convert_type(s, jnp.int8)
        return jnp.concatenate([q, s_bytes], axis=-1)
    return jnp.concatenate([q.astype(jnp.float32), s[..., None]], axis=-1)


def split_payload(payload: jnp.ndarray, block_size: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of :func:`fuse_payload`: -> (q int8, s f32).

    Dispatches on the payload dtype (int8 = bit-packed, f32 = fused
    lanes); int8 code values are exact in f32, so the round trip is
    lossless either way.
    """
    if payload.dtype == jnp.int8:
        q = payload[..., :block_size]
        s = jax.lax.bitcast_convert_type(payload[..., block_size:],
                                         jnp.float32)
        return q, s
    q = payload[..., :block_size].astype(jnp.int8)
    s = payload[..., block_size]
    return q, s


def compression_ratio(grads: Any, block_size: int = 256) -> float:
    """Bytes(int8+scales) / bytes(fp32) for a gradient pytree."""
    fp = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + -(-g.size // block_size) * 4
               for g in jax.tree.leaves(grads))
    return comp / fp
