"""Pipeline parallelism core: capacity-sized stages + 1F1B scheduling.

HetPipe direction (PAPERS.md): HetSeq absorbs capacity differences only
through batch sizing, which caps the model at what the smallest pod can
hold. Pipelining splits the *layer stack* into contiguous stages sized
by the same per-pod capacity scores the batch planner uses — fast pods
get more layers — so stage times equalise on skewed hardware exactly
like per-rank row counts do in the DP planner.

Reuse contract (ISSUE 8): the stage partition IS a
:class:`core.capacity.CapacityPlan` — ``plan_capacities(num_layers,
capacities, min_rows=1)`` assigns layers-per-stage by the identical
largest-remainder math, and ``plan_record``/``plan_from_record`` give
the checkpoint round-trip for free. ``stage_record`` is what
``steps.checkpoint_format`` embeds so a checkpoint saved under one
stage partition restores bit-exactly into another (params are stored
per-leaf; only the *placement* changes with the plan).

Scheduling: :func:`stage_schedule` builds per-stage op lists for the
classic 1F1B (warmup / steady 1F1B / drain) or GPipe (all forwards,
then all backwards) orders; :func:`program_order` merges them into ONE
deterministic global sequence by simulating the stages round-robin
under the dependency rules

    F(s, m)  needs  F(s-1, m)
    B(S-1,m) needs  F(S-1, m)
    B(s, m)  needs  B(s+1, m) and F(s, m)

which is the order ``launch/steps.py::_build_pipeline_step`` emits its
per-stage VJP segments and send/recv regions in, and the order the
modeled timeline below charges compute in. Backward ops for a fixed
stage occur in microbatch order, so per-leaf gradient accumulation at
each B event reproduces ``accumulate.unrolled_accumulate``'s add order
bit-for-bit.

Everything here is host-side (NumPy / pure python) — it runs at build
time and between steps, never inside jit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import capacity

SCHEDULES = ("1f1b", "gpipe")

# (kind, microbatch) op kinds in per-stage schedules / program orders.
FWD = "F"
BWD = "B"


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """Contiguous partition of a uniform layer stack into stages.

    ``plan.rows_per_rank[s]`` is the number of layers owned by stage
    ``s``; stages are contiguous in depth order (stage 0 owns the
    embedding, the last stage owns the head — transformer.py's
    ``staged_uniform_segments`` contract).
    """

    plan: capacity.CapacityPlan   # rows == layers, ranks == stages
    num_layers: int

    @property
    def num_stages(self) -> int:
        return self.plan.num_ranks

    @property
    def layers_per_stage(self) -> np.ndarray:
        return self.plan.rows_per_rank

    @property
    def boundaries(self) -> np.ndarray:
        """(S+1,) cumulative layer offsets; stage s owns [b[s], b[s+1])."""
        return np.concatenate(
            [[0], np.cumsum(self.layers_per_stage)]).astype(np.int64)

    def stage_ranges(self) -> List[Tuple[int, int]]:
        b = self.boundaries
        return [(int(b[s]), int(b[s + 1])) for s in range(self.num_stages)]

    def stage_of_layer(self, layer: int) -> int:
        if not 0 <= layer < self.num_layers:
            raise ValueError(
                f"layer {layer} outside stack of {self.num_layers}")
        return int(np.searchsorted(self.boundaries, layer, side="right") - 1)


def plan_stages(num_layers: int,
                capacities: Sequence[float]) -> StagePlan:
    """Capacity-sized contiguous stage partition of ``num_layers``.

    Every stage must end up with >= 1 layer: unlike DP ranks, a stage
    cannot run all-dummy (the forward must pass through it), so zero /
    negative capacities and more stages than layers are loud errors —
    drop the dead pod from the pipeline instead.
    """
    caps = np.asarray(capacities, np.float64)
    if caps.ndim != 1 or len(caps) == 0:
        raise ValueError("stage capacities must be a non-empty 1-D sequence")
    if np.any(caps <= 0):
        bad = np.nonzero(caps <= 0)[0].tolist()
        raise ValueError(
            f"stage capacities must be > 0 (stages {bad} are not): a "
            "pipeline stage cannot be all-dummy — remove the dead pod "
            "from the pipe axis instead")
    if num_layers < len(caps):
        raise ValueError(
            f"cannot cut {num_layers} layers into {len(caps)} stages "
            "(every stage needs >= 1 layer)")
    plan = capacity.plan_capacities(
        int(num_layers), caps, buffer_rows=int(num_layers), min_rows=1)
    assert int(plan.rows_per_rank.sum()) == int(num_layers)
    return StagePlan(plan=plan, num_layers=int(num_layers))


def uniform_stages(num_layers: int, num_stages: int) -> StagePlan:
    return plan_stages(num_layers, np.ones(num_stages))


def stage_record(splan: StagePlan) -> dict:
    """JSON-able checkpoint form (round-trips via capacity.plan_record)."""
    return {
        "num_layers": int(splan.num_layers),
        "plan": capacity.plan_record(splan.plan),
    }


def stage_from_record(record: dict) -> StagePlan:
    if not isinstance(record, dict):
        raise ValueError(
            f"malformed stage-plan record: expected dict, got "
            f"{type(record).__name__}")
    try:
        plan = capacity.plan_from_record(record["plan"])
        num_layers = int(record["num_layers"])
    except (KeyError, TypeError) as e:
        raise ValueError(f"malformed stage-plan record: {e!r}") from e
    splan = StagePlan(plan=plan, num_layers=num_layers)
    if int(plan.rows_per_rank.sum()) != num_layers:
        raise ValueError(
            f"malformed stage-plan record: layers_per_stage sums to "
            f"{int(plan.rows_per_rank.sum())}, num_layers={num_layers}")
    return splan


# --------------------------------------------------------------------------
# schedules


def stage_schedule(num_stages: int, num_microbatches: int,
                   schedule: str = "1f1b") -> List[List[Tuple[str, int]]]:
    """Per-stage op lists [(kind, microbatch), ...] in execution order.

    ``1f1b``: stage s runs ``min(M, S-1-s)`` warmup forwards, then
    alternates 1 forward / 1 backward (steady state), then drains the
    remaining backwards. Peak live activations on stage s are bounded
    by ``S - s`` microbatches instead of GPipe's M.

    ``gpipe``: all M forwards, then all M backwards.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule={schedule!r} not in {SCHEDULES}")
    S, M = int(num_stages), int(num_microbatches)
    if S < 1 or M < 1:
        raise ValueError(f"need num_stages >= 1 and num_microbatches >= 1, "
                         f"got {S}, {M}")
    out: List[List[Tuple[str, int]]] = []
    for s in range(S):
        ops: List[Tuple[str, int]] = []
        if schedule == "gpipe":
            ops += [(FWD, m) for m in range(M)]
            ops += [(BWD, m) for m in range(M)]
        else:
            warmup = min(M, S - 1 - s)
            ops += [(FWD, m) for m in range(warmup)]
            f, b = warmup, 0
            while f < M:            # steady 1F1B
                ops.append((FWD, f)); f += 1
                ops.append((BWD, b)); b += 1
            while b < M:            # drain
                ops.append((BWD, b)); b += 1
        out.append(ops)
    return out


def program_order(num_stages: int, num_microbatches: int,
                  schedule: str = "1f1b") -> List[Tuple[int, str, int]]:
    """Deterministic global [(stage, kind, microbatch), ...] order.

    Round-robin simulation: sweep the stages, each issuing its next
    scheduled op iff its dependencies have already been issued. Raises
    if the schedule deadlocks (cross-check on stage_schedule).
    """
    per_stage = stage_schedule(num_stages, num_microbatches, schedule)
    S = int(num_stages)
    ptr = [0] * S
    done = set()
    order: List[Tuple[int, str, int]] = []

    def ready(s: int, kind: str, m: int) -> bool:
        if kind == FWD:
            return s == 0 or (s - 1, FWD, m) in done
        if s == S - 1:
            return (s, FWD, m) in done
        return (s + 1, BWD, m) in done and (s, FWD, m) in done

    remaining = sum(len(ops) for ops in per_stage)
    while remaining:
        progressed = False
        for s in range(S):
            if ptr[s] >= len(per_stage[s]):
                continue
            kind, m = per_stage[s][ptr[s]]
            if ready(s, kind, m):
                order.append((s, kind, m))
                done.add((s, kind, m))
                ptr[s] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            stuck = {s: per_stage[s][ptr[s]] for s in range(S)
                     if ptr[s] < len(per_stage[s])}
            raise ValueError(f"schedule deadlock: {stuck}")
    return order


# --------------------------------------------------------------------------
# modeled step times (host-side; benchmarks/pipeline_bench.py constants)


def modeled_pipeline_step_time(
    splan: StagePlan,
    speeds: Sequence[float],
    *,
    num_microbatches: int,
    mb_rows: int,
    row_layer_time: float,
    act_bytes_per_mb: float,
    dcn_bytes_per_s: float,
    bwd_mult: float = 2.0,
    schedule: str = "1f1b",
) -> float:
    """Event-driven makespan of one pipelined step (seconds).

    Per-microbatch stage compute: ``mb_rows * layers_s * row_layer_time
    / speeds[s]`` forward, ``bwd_mult``x that backward. Stage boundary
    traffic (activation forward + cotangent backward) is charged to the
    sending op at DCN rate. Ops run serially per stage in schedule
    order; cross-stage dependencies follow :func:`program_order`.
    """
    speeds = np.asarray(speeds, np.float64)
    S = splan.num_stages
    if len(speeds) != S:
        raise ValueError(f"{len(speeds)} speeds for {S} stages")
    layers = splan.layers_per_stage.astype(np.float64)
    send = act_bytes_per_mb / dcn_bytes_per_s
    t_f = mb_rows * layers * row_layer_time / speeds
    t_f = t_f + np.where(np.arange(S) < S - 1, send, 0.0)   # F send to s+1
    t_b = bwd_mult * mb_rows * layers * row_layer_time / speeds
    t_b = t_b + np.where(np.arange(S) > 0, send, 0.0)       # B send to s-1

    avail = np.zeros(S)
    done: Dict[Tuple[int, str, int], float] = {}
    for (s, kind, m) in program_order(S, num_microbatches, schedule):
        if kind == FWD:
            dep = done.get((s - 1, FWD, m), 0.0) if s > 0 else 0.0
            dur = float(t_f[s])
        else:
            dep = (done[(s, FWD, m)] if s == S - 1
                   else max(done[(s + 1, BWD, m)], done[(s, FWD, m)]))
            dur = float(t_b[s])
        start = max(float(avail[s]), dep)
        done[(s, kind, m)] = start + dur
        avail[s] = start + dur
    return max(done.values())


def modeled_dp_step_time(
    num_layers: int,
    capacities: Sequence[float],
    *,
    global_rows: int,
    row_layer_time: float,
    param_bytes_per_layer: float,
    dcn_bytes_per_s: float,
    bwd_mult: float = 2.0,
) -> float:
    """Pure-DP baseline on the same pods: capacity-sized batch shares.

    Every rank computes the FULL stack over its row share (rows from
    the same largest-remainder planner) and then syncs the FULL
    gradient over DCN — the term pipelining removes by exchanging only
    stage-boundary activations instead.
    """
    plan = capacity.plan_capacities(int(global_rows), capacities)
    speeds = np.asarray(capacities, np.float64)
    rows = plan.rows_per_rank.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        per_rank = np.where(
            speeds > 0,
            rows * num_layers * row_layer_time * (1.0 + bwd_mult) / speeds,
            0.0)
    sync = num_layers * param_bytes_per_layer / dcn_bytes_per_s
    return float(per_rank.max()) + sync
