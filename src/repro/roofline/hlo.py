"""Optimized-HLO text analysis: FLOPs, HBM bytes, collective bytes.

``compiled.cost_analysis()`` has two blind spots the roofline cannot
live with: (1) it counts every ``while`` body ONCE — a scanned layer
stack under-reports FLOPs by ~num_layers x; (2) it reports no collective
traffic at all. This module rebuilds whole-program costs from
``compiled.as_text()``:

  * call-graph weights: ENTRY has weight 1; a while body inherits
    weight x trip_count (trip count recovered from the loop-condition
    computation's comparison constant); fusion bodies inherit their
    caller's weight;
  * FLOPs: every ``dot`` line contributes 2 x result_elems x
    contraction_size (operand shapes resolved through a per-computation
    symbol table — scheduled HLO prints operands as bare refs);
    ``convolution`` approximated as 2 x result x kernel_size;
  * HBM bytes: per-instruction I/O (result + resolved operands) at
    computation level, fusion bodies excluded (their internals live in
    registers/VMEM; the fusion instruction's own I/O is what moves);
  * collectives: ``all-gather``/``all-reduce``/``reduce-scatter``/
    ``all-to-all``/``collective-permute`` result bytes scaled by the
    ring-model wire cost, split ICI vs DCN by whether the replica group
    crosses a 256-chip pod boundary.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")

_BYTE_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "while", "conditional", "call",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(text)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d.strip())


def _strip_meta(line: str) -> str:
    return line.split(", metadata=")[0]


def _line_op(line: str) -> str:
    rhs = line.split("=", 1)[1] if "=" in line else line
    m = _OP_RE.search(_strip_meta(rhs))
    return m.group(1) if m else ""


def _result_text(line: str) -> str:
    """Text between '=' and the op name (the result shape)."""
    rhs = line.split("=", 1)[1] if "=" in line else line
    m = _OP_RE.search(_strip_meta(rhs))
    return rhs[:m.start()] if m else rhs


def _operand_names(line: str) -> List[str]:
    """Operand refs inside op(...) — before any attribute list."""
    rhs = _strip_meta(line.split("=", 1)[1] if "=" in line else line)
    m = _OP_RE.search(rhs)
    if not m:
        return []
    args = rhs[m.end():]
    # cut at the matching close paren (flat scan; nested parens rare in
    # operand lists of scheduled HLO)
    depth = 1
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return re.findall(r"%([\w.\-]+)", args)


# --------------------------------------------------------------------------
# computations, symbol tables, call-graph weights
# --------------------------------------------------------------------------


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if line.startswith("HloModule"):
            continue
        if cur is None:
            if line.rstrip().endswith("{") and "(" in line:
                m = _HEADER_RE.match(line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if line.startswith("}") or line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.rstrip())
    return comps


def _symbol_table(lines: List[str]) -> Dict[str, str]:
    """instr name -> result-shape text."""
    table: Dict[str, str] = {}
    for line in lines:
        m = _NAME_RE.match(line)
        if m:
            table[m.group(1)] = _result_text(line)
    return table


def _entry_name(hlo: str) -> Optional[str]:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                return m.group(1)
    return None


def _call_weights(hlo: str, comps: Dict[str, List[str]]
                  ) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """computation -> execution weight; computation -> is_fusion_body."""
    edges: Dict[str, List[Tuple[str, float]]] = {}
    fusion_body: Dict[str, bool] = {}

    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if " while(" in line and wm:
                cond, body = wm.group(1), wm.group(2)
                consts: List[int] = []
                for cl in comps.get(cond, []):
                    consts += [int(x) for x in _CONST_RE.findall(cl)]
                trip = max(consts) if consts else 1
                edges.setdefault(name, []).append((body, float(max(trip,
                                                                   1))))
                continue
            cm = _CALLS_RE.search(line)
            if cm:
                edges.setdefault(name, []).append((cm.group(1), 1.0))
                if " fusion(" in line:
                    fusion_body[cm.group(1)] = True

    entry = _entry_name(hlo) or (list(comps)[-1] if comps else None)
    weights: Dict[str, float] = {c: 0.0 for c in comps}
    if entry in weights:
        weights[entry] = 1.0
    for _ in range(8):                    # nested loops: iterate to fixpoint
        changed = False
        for name in list(comps):
            w = weights.get(name, 0.0)
            if w <= 0:
                continue
            for callee, mult in edges.get(name, []):
                if callee in weights and w * mult > weights[callee]:
                    weights[callee] = w * mult
                    changed = True
        if not changed:
            break
    return weights, fusion_body


# --------------------------------------------------------------------------
# program costs
# --------------------------------------------------------------------------


def _dot_flops(line: str, table: Dict[str, str]) -> int:
    res_elems = 1
    for d in _first_shape_dims(_result_text(line)):
        res_elems *= d
    ops = _operand_names(line)
    contract = 1
    if ops:
        lhs_dims = _first_shape_dims(table.get(ops[0], ""))
        m = _DOT_CONTRACT_RE.search(line)
        if m and lhs_dims:
            for idx in m.group(1).split(","):
                if idx.strip():
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
    return 2 * res_elems * contract


def _conv_flops(line: str) -> int:
    res_elems = 1
    for d in _first_shape_dims(_result_text(line)):
        res_elems *= d
    m = re.search(r"window=\{size=([0-9x]+)", line)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2 * res_elems * k


@dataclasses.dataclass
class ProgramCosts:
    flops: float                   # per-device, trip-weighted
    hbm_bytes: float               # per-device, trip-weighted (estimate)
    dot_count: int


_SLICE_LIKE = ("dynamic-slice", "gather", "slice")


def _instr_bytes(line: str, op: str, name: str,
                 table: Dict[str, str]) -> int:
    """HBM traffic of one instruction.

    Slice-like ops read only the addressed window, not their (possibly
    loop-invariant, stacked) operand — charging the full operand per
    trip would overstate a layer scan's traffic by ~L x. Rules:
      * dynamic-slice / gather / slice: 2 x result (read window + write)
      * dynamic-update-slice / scatter (incl. fused): 2 x the non-
        buffer operands (the buffer operand is result-shaped and only
        its window is touched)
      * everything else: result + resolved operand bytes.
    """
    res = _shape_bytes(_result_text(line))
    lowered_name = name.lower()
    if op in _SLICE_LIKE or any(s in lowered_name for s in _SLICE_LIKE):
        return 2 * res
    if (op in ("dynamic-update-slice", "scatter")
            or "dynamic-update-slice" in lowered_name
            or "scatter" in lowered_name):
        other = 0
        for o in _operand_names(line):
            b = _shape_bytes(table.get(o, ""))
            if b != res:                      # skip the buffer operand
                other += b
        return 2 * other if other else 2 * res
    io = res
    for o in _operand_names(line):
        io += _shape_bytes(table.get(o, ""))
    return io


def program_costs(hlo: str) -> ProgramCosts:
    comps = _split_computations(hlo)
    weights, fusion_body = _call_weights(hlo, comps)
    flops = 0.0
    bytes_ = 0.0
    dots = 0
    for name, lines in comps.items():
        w = weights.get(name, 0.0)
        if w <= 0:
            continue
        table = _symbol_table(lines)
        in_fusion = fusion_body.get(name, False)
        for line in lines:
            op = _line_op(line)
            if op == "dot":
                flops += w * _dot_flops(line, table)
                dots += 1
            elif op == "convolution":
                flops += w * _conv_flops(line)
            if not in_fusion and op and op not in _BYTE_SKIP_OPS:
                m = _NAME_RE.match(line)
                iname = m.group(1) if m else ""
                bytes_ += w * _instr_bytes(line, op, iname, table)
    return ProgramCosts(flops=flops, hbm_bytes=bytes_, dot_count=dots)


# --------------------------------------------------------------------------
# collectives
# --------------------------------------------------------------------------


def _group_info(line: str, pod_size: int = 256) -> Tuple[int, int]:
    """(group size, pods spanned) from the replica_groups annotation.

    Iota groups ``[G,P]<=[dims]T(perm)`` are materialized (device counts
    here are <= 512) so transposed layouts — e.g. the cross-pod pairs
    ``[256,2]<=[2,256]T(1,0)`` — classify correctly.
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, per_group = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        if total <= 65536:
            import numpy as _np
            ids = _np.arange(total).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            first = ids.reshape(ngroups, per_group)[0]
            pods = len({int(i) // pod_size for i in first})
            return per_group, max(pods, 1)
        return per_group, 2 if per_group > pod_size else 1
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        pods = {i // pod_size for i in ids}
        return max(len(ids), 1), max(len(pods), 1)
    return 1, 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: Dict[str, int]
    ici_bytes: int                  # per-device wire bytes, intra-pod
    dcn_bytes: int                  # per-device wire bytes, cross-pod
    count: int

    @property
    def total_bytes(self) -> int:
        return self.ici_bytes + self.dcn_bytes


def collective_stats(hlo: str, pod_size: int = 256) -> CollectiveStats:
    comps = _split_computations(hlo)
    weights, _ = _call_weights(hlo, comps)

    by_type: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    ici = 0
    dcn = 0
    count = 0

    for name, lines in comps.items():
        w = weights.get(name, 0.0)
        if w <= 0:
            continue
        for line in lines:
            op = _line_op(line)
            base = op.replace("-start", "")
            if op.endswith("-done") or base not in _COLLECTIVES:
                continue
            size = _shape_bytes(_result_text(line))
            n, pods = _group_info(line, pod_size)
            if base == "all-reduce":
                wire = 2 * size * (n - 1) // max(n, 1)
            elif base == "collective-permute":
                wire = size
            else:
                wire = size * (n - 1) // max(n, 1)
            wire = int(wire * w)
            by_type[base] += wire
            # pod-crossing groups decompose hierarchically (XLA and any
            # sane runtime): the cross-pod leg moves (pods-1)/pods of
            # the payload over DCN, the rest stays on ICI
            if pods > 1:
                dcn_part = int(size * (pods - 1) // pods * w)
                dcn += min(dcn_part, wire)
                ici += max(wire - dcn_part, 0)
            else:
                ici += wire
            count += int(w)
    return CollectiveStats(bytes_by_type=by_type, ici_bytes=ici,
                           dcn_bytes=dcn, count=count)
