"""Three-term roofline report from dry-run artifacts (TPU v5e target).

Per (arch, shape, mesh) cell:

    compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes / (chips * HBM_BW)
    collective term = ici_bytes / ICI_BW + dcn_bytes / DCN_BW
                      (per-device wire bytes from roofline/hlo.py)

The dominant term is the bottleneck the perf loop iterates on;
MODEL_FLOPS/HLO_FLOPs shows how much compiled compute is useful
(catches remat recompute and dispatch waste).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12            # bf16
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9 * 4              # ~50 GB/s/link, 4 links usable per chip (2D)
DCN_BW = 25e9                  # cross-pod per-chip share (assumed, DCN)


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # whole-program FLOPs (all chips)
    hlo_bytes: float               # whole-program HBM traffic
    ici_bytes: float               # per-device wire bytes
    dcn_bytes: float
    model_flops: float             # 6*N*D (dense) / 6*N_active*D (MoE)
    kind: str = "train"

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.ici_bytes / ICI_BW + self.dcn_bytes / DCN_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time: max of the three terms (overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Useful-model-FLOPs utilization at the bound: the score."""
        if self.step_time_bound <= 0:
            return 0.0
        return (self.model_flops / (self.chips * PEAK_FLOPS)
                ) / self.step_time_bound

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "kind": self.kind,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops_for(arch_params_active: int, tokens: int,
                    kind: str) -> float:
    """6*N*D for training, 2*N*D for inference (fwd only)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * arch_params_active * tokens


def format_table(rows, hillclimbed=()) -> str:
    hdr = (f"| {'arch':22s} | {'shape':12s} | {'mesh':6s} | "
           f"{'t_comp(s)':>10s} | {'t_mem(s)':>10s} | {'t_coll(s)':>10s} | "
           f"{'dominant':>10s} | {'useful':>7s} | {'roofl%':>7s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    out = [hdr, sep]
    for r in rows:
        mark = " *" if (r.arch, r.shape) in hillclimbed else ""
        out.append(
            f"| {r.arch + mark:22s} | {r.shape:12s} | {r.mesh:6s} | "
            f"{r.t_compute:10.4f} | {r.t_memory:10.4f} | "
            f"{r.t_collective:10.4f} | {r.dominant:>10s} | "
            f"{r.useful_flops_frac:7.2f} | {100 * r.roofline_frac:6.1f}% |")
    return "\n".join(out)


def load_rows(path: str):
    with open(path) as fh:
        data = json.load(fh)
    return [RooflineRow(**{k: v for k, v in row.items()
                           if k in RooflineRow.__dataclass_fields__})
            for row in data]
