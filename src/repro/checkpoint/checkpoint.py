"""M7 — full-state checkpointing (async, atomic, sharded, durable).

The paper's checkpoint carries: model parameters, completed epochs,
completed steps, optimizer + LR-scheduler state, and the RNG seed. Ours
additionally persists the capacity plan (as structured JSON that
round-trips into a real ``CapacityPlan``) and the data-stream position
(epoch + batches consumed within it) so an elastic restart with a
*different* mesh resumes the identical global sample stream
(core/elastic.py invariant).

On-disk layout (version 3): ``<dir>/step_<N>/``

  arrays_host<k>.npz
               host ``k``'s shards of the state, keyed by the escaped
               ``/``-joined leaf path (repack.path_key). Packed 2-D
               stacks (``opt/m`` / ``opt/v`` as (num_buckets,
               bucket_elems)) are split by bucket rows across hosts
               along the extents in the layout record
               (core/buckets.py::host_shard_extents); the (ranks, ...)
               ``err`` stack is split by rank; every other leaf is
               written whole by exactly one host, balanced by bytes.
               The host count comes from ``meta["format"]["hosts"]``
               (launch/steps.py::checkpoint_format records the pod
               count) — on a real fleet each host writes only its own
               file instead of gathering onto one writer.
  manifest.json
               crash-consistency record: per-file byte sizes and
               sha256 content checksums, plus the key -> shard-extent
               map each file holds. Restore refuses the step on any
               mismatch and falls back to the previous committed one.
  meta.json    step / epoch / seed / structured plan / data-stream
               position, plus the ``"format"`` block (format version,
               packed fields, versioned ``BucketLayout`` record +
               fingerprint, writing overlap mode, host count).
  _DONE        commit marker, written into the temp dir before the
               atomic rename — a crash at ANY point leaves either a
               committed ``step_<N>`` or an ignorable ``.tmp``

Durability: every file is fsynced after write, the temp directory is
fsynced before the atomic rename, and the parent directory after it —
a committed ``step_<N>`` is on the platter, not in the page cache.
Version-2 checkpoints (one gathered ``arrays.npz``, no manifest) still
load; pass ``format_version=2`` to ``save`` to write one.

Repack-on-restore: ``restore`` reassembles the per-host shards into the
flat ``{path key: array}`` stream (validating manifest coverage) and
hands it through ``repack.adapt_arrays`` before unflattening, so a
checkpoint written under any layout (packed moments of any bucket grid,
pytree moments, flat or per-leaf error state, any reduction rank count)
restores into whatever layout the caller's template expects —
packed<->pytree and grid-to-grid translations go through the
layout-invariant flat stream and are bit-exact. Across a rank-count
change the summed error-feedback residual is distributed over the new
ranks' stream extents (sum conserved bit-exactly, no rank parked with
the whole residual — see checkpoint/repack.py).

Async: ``save`` snapshots device arrays to host (blocking, cheap), then
writes files on a background thread — the train loop never waits on
disk. Callers MUST ``wait()`` on every exit path (launch/train.py does)
or the final checkpoint of a run can be lost with the daemon thread.
"""
from __future__ import annotations

import glob
import hashlib
import io
import json
import logging
import os
import shutil
import threading
import time
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import repack
from repro.core.buckets import host_shard_extents
from repro.core.capacity import CapacityPlan, plan_from_record, plan_record

_DONE = "_DONE"
_PLAN_TAG = "__capacity_plan__"
_MANIFEST = "manifest.json"
_META = "meta.json"

logger = logging.getLogger(__name__)


class CheckpointCorruptError(RuntimeError):
    """A committed step failed manifest/content validation (truncated or
    bit-flipped shard, missing manifest, unreadable file). ``restore``
    falls back to the previous committed step unless the caller asked
    for this step explicitly."""


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v)
            for k, v in repack.flatten_with_paths(tree).items()}


def _cast_is_lossy(src: np.dtype, dst: np.dtype) -> bool:
    """Whether restoring a ``src`` leaf into a ``dst`` template leaf
    loses information (fp32 ckpt -> bf16 template, float -> int, int64
    -> int32). Extension float dtypes (bfloat16) fail ``np.can_cast``,
    so float pairs compare precision envelopes via ``finfo``; anything
    undecidable counts as lossy."""
    import jax.numpy as jnp

    if src == dst:
        return False
    try:
        fs, fd = jnp.finfo(src), jnp.finfo(dst)
        return not (fd.nmant >= fs.nmant and fd.maxexp >= fs.maxexp
                    and fd.minexp <= fs.minexp)
    except (TypeError, ValueError):
        pass
    try:
        return not np.can_cast(src, dst, casting="safe")
    except TypeError:
        return True


def _unflatten_like(template: Any, arrays: Dict[str, np.ndarray],
                    allow_cast: bool = False) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    cast = []
    for path, leaf in paths_leaves[0]:
        key = repack.path_key(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        src, dst = np.dtype(arr.dtype), np.dtype(leaf.dtype)
        if src != dst:
            if _cast_is_lossy(src, dst) and not allow_cast:
                raise ValueError(
                    f"lossy dtype cast for '{key}': checkpoint {src} "
                    f"-> template {dst} would lose precision; pass "
                    f"allow_cast=True to restore() to accept it")
            cast.append((key, src, dst))
        leaves.append(arr.astype(leaf.dtype))
    if cast:
        logger.warning(
            "checkpoint restore cast %d leaf/leaves to the template "
            "dtype (first: '%s' %s -> %s)", len(cast), cast[0][0],
            cast[0][1], cast[0][2])
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def _json_default(obj: Any) -> Any:
    """Structured meta serialization — never silently stringify.

    ``CapacityPlan`` becomes a tagged record that ``_meta_hook``
    rebuilds into a real plan on load; numpy scalars/arrays become
    plain JSON numbers/lists. Anything else raises loudly at save time
    (surfaced by ``wait()``) instead of burying a useless ``str()`` in
    meta.json.
    """
    if isinstance(obj, CapacityPlan):
        return {_PLAN_TAG: plan_record(obj)}
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, jax.Array):
        return np.asarray(obj).tolist()
    raise TypeError(
        f"checkpoint meta value of type {type(obj).__name__!r} is not "
        f"JSON-serializable — give it a structured record (see "
        f"plan_record) instead of relying on str()")


def _meta_hook(d: Dict) -> Any:
    if set(d) == {_PLAN_TAG}:
        return plan_from_record(d[_PLAN_TAG])
    return d


# ---- durability primitives ------------------------------------------------


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_json_synced(path: str, obj: Any, **dump_kw: Any) -> None:
    with open(path, "w") as fh:
        json.dump(obj, fh, indent=1, **dump_kw)
        fh.flush()
        os.fsync(fh.fileno())


def _write_bytes_synced(path: str, data: bytes) -> Dict[str, Any]:
    """Write + fsync one manifest-tracked file; the size/checksum come
    from the in-memory bytes, so the save path never re-reads what it
    just wrote (``_sha256`` re-reads only on the restore side)."""
    with open(path, "wb") as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
    return {"bytes": len(data),
            "sha256": hashlib.sha256(data).hexdigest()}


def _shard_across_hosts(flat: Dict[str, np.ndarray], fmt: Dict,
                        num_hosts: int
                        ) -> Tuple[List[Dict[str, np.ndarray]],
                                   List[Dict[str, Dict]]]:
    """Partition the flat array dict over ``num_hosts`` writer files.

    Packed 2-D stacks (``packed_fields``) split by bucket rows, the
    (ranks, ...) err stack by rank — both along the layout record's
    host extents when they match, else a balanced split. Everything
    else is written whole by one host (greedy byte balance). Returns
    per-host ``{key: shard}`` dicts plus the manifest key records
    (full shape, and the ``[lo, hi)`` row extent for split keys).
    """
    packed = set(fmt.get("packed_fields") or ())
    layout = fmt.get("layout") or {}
    host_arrays: List[Dict[str, np.ndarray]] = [
        {} for _ in range(num_hosts)]
    key_records: List[Dict[str, Dict]] = [{} for _ in range(num_hosts)]
    loads = [0] * num_hosts
    for key, arr in flat.items():
        row_split = (num_hosts > 1 and arr.ndim >= 2
                     and (key in packed or key == repack.ERR_GROUP))
        if row_split:
            rec_ext = layout.get("host_extents")
            extents = (
                [(int(lo), int(hi)) for lo, hi in rec_ext]
                if key in packed and rec_ext is not None
                and len(rec_ext) == num_hosts
                and rec_ext[-1][1] == arr.shape[0]
                else host_shard_extents(arr.shape[0], num_hosts))
            for h, (lo, hi) in enumerate(extents):
                if hi <= lo:
                    continue
                host_arrays[h][key] = arr[lo:hi]
                key_records[h][key] = {"shape": list(arr.shape),
                                       "rows": [lo, hi]}
                loads[h] += arr[lo:hi].nbytes
        else:
            h = min(range(num_hosts), key=lambda i: loads[i])
            host_arrays[h][key] = arr
            key_records[h][key] = {"shape": list(arr.shape)}
            loads[h] += arr.nbytes
    return host_arrays, key_records


def _assemble_shards(npz_arrays: Dict[str, Dict[str, np.ndarray]],
                     manifest: Dict) -> Dict[str, np.ndarray]:
    """Per-host shard dicts -> the full flat ``{key: array}`` stream.

    Validates that split keys cover ``[0, shape[0])`` contiguously and
    reassemble to the recorded full shape.
    """
    arrays: Dict[str, np.ndarray] = {}
    shards: Dict[str, List[Tuple[int, int, np.ndarray, Tuple[int, ...]]]]
    shards = {}
    for fname, rec in manifest["files"].items():
        if fname not in npz_arrays:
            continue
        loaded = npz_arrays[fname]
        for key, krec in rec.get("keys", {}).items():
            arr = loaded[key]
            shape = tuple(int(d) for d in krec["shape"])
            if "rows" in krec:
                lo, hi = (int(x) for x in krec["rows"])
                shards.setdefault(key, []).append((lo, hi, arr, shape))
            else:
                if tuple(arr.shape) != shape:
                    raise CheckpointCorruptError(
                        f"'{key}' in {fname} has shape {arr.shape}, "
                        f"manifest records {shape}")
                arrays[key] = arr
    for key, parts in shards.items():
        parts.sort(key=lambda t: t[0])
        full = parts[0][3]
        expect = 0
        for lo, hi, arr, shape in parts:
            if shape != full or lo != expect or arr.shape[0] != hi - lo:
                raise CheckpointCorruptError(
                    f"shard coverage broken for '{key}': extent "
                    f"[{lo}, {hi}) after row {expect} of {full}")
            expect = hi
        if expect != full[0]:
            raise CheckpointCorruptError(
                f"shards of '{key}' cover {expect} rows, manifest "
                f"records {full[0]}")
        arrays[key] = np.concatenate([p[2] for p in parts], axis=0)
    return arrays


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 io_retries: int = 3, io_backoff_s: float = 0.05,
                 fault_hook=None):
        """``io_retries``: total write attempts per save for transient
        ``OSError`` (disk-full blips, NFS hiccups) — the background
        writer retries with exponential backoff (``io_backoff_s``,
        doubling) and re-raises through ``wait()`` after the last
        attempt. Each attempt rebuilds the ``.tmp`` dir from scratch,
        so the fsync + atomic-rename commit semantics are unchanged: a
        step is either fully committed or absent.

        ``fault_hook``: optional ``hook(step, tmp_path)`` called at the
        start of every write attempt — the chaos engine's
        ``ckpt_io_fail`` fault (core/chaos.py) raises ``OSError`` here
        to exercise the retry path deterministically."""
        self.directory = directory
        self.keep = keep
        self.io_retries = max(int(io_retries), 1)
        self.io_backoff_s = float(io_backoff_s)
        self.fault_hook = fault_hook
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: List[BaseException] = []
        self._warned_names: set = set()

    # ---- save ------------------------------------------------------------

    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             block: bool = False,
             format_version: Optional[int] = None) -> None:
        """Snapshot now, write in the background (one writer at a time).

        ``format_version``: on-disk layout to write — 3 (default,
        per-host shards + manifest) or 2 (one gathered arrays.npz, for
        migration tests / old readers). The host count for v3 comes
        from ``meta["format"]["hosts"]`` (default 1).
        """
        version = int(format_version if format_version is not None
                      else repack.FORMAT_VERSION)
        if version not in (2, 3):
            raise ValueError(f"unsupported checkpoint format_version "
                             f"{version} (writable: 2, 3)")
        self.wait()                       # at most one in-flight write
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        flat = _flatten_with_paths(host_state)   # key collisions raise HERE
        meta = dict(meta or {})
        meta["step"] = int(step)
        fmt = dict(meta.get("format") or {})
        fmt["version"] = version          # describe what is written
        meta["format"] = fmt
        num_hosts = max(int(fmt.get("hosts") or 1), 1)

        def write():
            delay = self.io_backoff_s
            for attempt in range(1, self.io_retries + 1):
                try:
                    self._write(step, flat, meta, version, num_hosts)
                    self._rotate()
                    return
                except OSError as e:      # transient IO: bounded retry
                    if attempt >= self.io_retries:
                        self._error.append(e)
                        return
                    logger.warning(
                        "checkpoint write for step %d failed (%s) — "
                        "attempt %d/%d, retrying in %.0f ms", step, e,
                        attempt, self.io_retries, delay * 1e3)
                    time.sleep(delay)
                    delay *= 2.0
                except BaseException as e:  # surfaced on next wait()
                    self._error.append(e)
                    return

        self._thread = threading.Thread(target=write, daemon=True,
                                        name=f"ckpt-write-{step}")
        self._thread.start()
        if block:
            self.wait()

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               meta: Dict, version: int, num_hosts: int) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        if self.fault_hook is not None:
            self.fault_hook(step, tmp)
        if version == 2:
            path = os.path.join(tmp, "arrays.npz")
            np.savez(path, **flat)
            _fsync_path(path)
            _write_json_synced(os.path.join(tmp, _META), meta,
                               default=_json_default)
        else:
            host_arrays, key_records = _shard_across_hosts(
                flat, meta.get("format") or {}, num_hosts)
            files: Dict[str, Dict] = {}
            for h, arrays in enumerate(host_arrays):
                fname = f"arrays_host{h}.npz"
                # serialize to memory once: the checksum is computed
                # from the same bytes that hit the disk, without
                # re-reading the file (a tee-hash around the file
                # object would hash stale bytes — zipfile seeks back
                # to patch local headers on seekable streams)
                buf = io.BytesIO()
                np.savez(buf, **arrays)
                files[fname] = {
                    **_write_bytes_synced(os.path.join(tmp, fname),
                                          buf.getvalue()),
                    "keys": key_records[h]}
            meta_bytes = json.dumps(meta, indent=1,
                                    default=_json_default).encode()
            files[_META] = _write_bytes_synced(
                os.path.join(tmp, _META), meta_bytes)
            _write_json_synced(
                os.path.join(tmp, _MANIFEST),
                {"manifest_version": 1, "format_version": version,
                 "step": int(step), "hosts": num_hosts, "files": files})
        with open(os.path.join(tmp, _DONE), "w") as fh:
            fh.write("ok")
            fh.flush()
            os.fsync(fh.fileno())
        _fsync_path(tmp)                  # directory entries durable
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit
        _fsync_path(self.directory)       # ... and the rename itself

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- load ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            try:
                s = int(name[5:])
            except ValueError:
                if name not in self._warned_names:
                    self._warned_names.add(name)
                    logger.warning(
                        "ignoring non-checkpoint entry %r in %s (does "
                        "not parse as step_<N>)", name, self.directory)
                continue
            path = os.path.join(self.directory, name)
            if os.path.exists(os.path.join(path, _DONE)):
                out.append(s)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _validate_manifest(self, path: str) -> Dict:
        """Load + verify manifest.json: files exist, sizes and sha256
        checksums match. Raises :class:`CheckpointCorruptError`."""
        man_path = os.path.join(path, _MANIFEST)
        if not os.path.exists(man_path):
            raise CheckpointCorruptError(
                f"{path} holds per-host shard files but no {_MANIFEST}")
        try:
            with open(man_path) as fh:
                manifest = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(
                f"unreadable {_MANIFEST} in {path}: {e}") from e
        for fname, rec in manifest.get("files", {}).items():
            fpath = os.path.join(path, fname)
            if not os.path.exists(fpath):
                raise CheckpointCorruptError(
                    f"manifest names missing file '{fname}' in {path}")
            size = os.path.getsize(fpath)
            if size != int(rec["bytes"]):
                raise CheckpointCorruptError(
                    f"'{fname}' is {size} bytes, manifest records "
                    f"{rec['bytes']} (truncated?)")
            digest = _sha256(fpath)
            if digest != rec["sha256"]:
                raise CheckpointCorruptError(
                    f"content checksum mismatch for '{fname}': "
                    f"{digest[:12]}... != recorded "
                    f"{rec['sha256'][:12]}...")
        return manifest

    def _load_step(self, step: int) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Read one committed step into (flat arrays, meta).

        Raises FileNotFoundError when the step was never committed and
        :class:`CheckpointCorruptError` when its content fails
        validation (manifest mismatch, unreadable files).
        """
        path = os.path.join(self.directory, f"step_{step:010d}")
        if not os.path.exists(os.path.join(path, _DONE)):
            raise FileNotFoundError(f"checkpoint {path} incomplete")
        host_files = sorted(glob.glob(
            os.path.join(path, "arrays_host*.npz")))
        v3 = host_files or os.path.exists(os.path.join(path, _MANIFEST))
        try:
            if v3:
                manifest = self._validate_manifest(path)
                npz_arrays: Dict[str, Dict[str, np.ndarray]] = {}
                for fname, rec in manifest["files"].items():
                    if not fname.endswith(".npz"):
                        continue
                    with np.load(os.path.join(path, fname)) as z:
                        loaded = {k: z[k] for k in z.files}
                    if set(loaded) != set(rec.get("keys", {})):
                        raise CheckpointCorruptError(
                            f"'{fname}' holds keys "
                            f"{sorted(loaded)}, manifest records "
                            f"{sorted(rec.get('keys', {}))}")
                    npz_arrays[fname] = loaded
                arrays = _assemble_shards(npz_arrays, manifest)
            else:
                arrays_path = os.path.join(path, "arrays.npz")
                if not os.path.exists(arrays_path):
                    raise CheckpointCorruptError(
                        f"{path} holds neither arrays.npz nor per-host "
                        f"shard files")
                with np.load(arrays_path) as z:
                    arrays = {k: z[k] for k in z.files}
            with open(os.path.join(path, _META)) as fh:
                meta = json.load(fh, object_hook=_meta_hook)
        except (OSError, zipfile.BadZipFile, json.JSONDecodeError,
                KeyError) as e:
            raise CheckpointCorruptError(
                f"unreadable checkpoint {path}: {e!r}") from e
        return arrays, meta

    def restore(self, template: Any, step: Optional[int] = None,
                expected_overlap: Optional[str] = None,
                allow_cast: bool = False) -> Tuple[Any, Dict]:
        """Returns (state shaped like ``template``, meta).

        The template may be differently *sharded* than at save time
        (elastic re-mesh) — placement is the caller's (device_put) —
        and may expect a different optimizer-state LAYOUT than was
        saved: packed moments of any bucket grid, pytree moments, and
        flat/per-leaf error state all translate through
        ``repack.adapt_arrays`` (bit-exact, see checkpoint/repack.py).
        Template leaves only need ``.shape``/``.dtype`` —
        ShapeDtypeStructs work.

        Durability: a step whose manifest validation fails (truncated
        or bit-flipped shard, missing manifest) is rejected; with
        ``step=None`` the restore falls back to the previous committed
        step (logged loudly), with an explicit ``step`` the
        :class:`CheckpointCorruptError` propagates.

        ``allow_cast``: restoring into a template whose leaf dtype
        cannot represent the saved values exactly (fp32 checkpoint into
        a bf16 template) raises unless this is True; any dtype cast at
        all is logged.

        ``expected_overlap``: the restoring config's
        ``HetConfig.overlap`` mode. The checkpoint records which mode
        wrote it (``meta["format"]["overlap"]``); a mismatch still
        restores — the repack handles the layout translation — but is
        LOGGED, never silently adapted, because a packed->pytree (or
        reverse) translation is a real layout change the operator
        should see.
        """
        explicit = step is not None
        candidates = ([step] if explicit
                      else list(reversed(self.all_steps())))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_err: Optional[BaseException] = None
        arrays = meta = None
        chosen = None
        for s in candidates:
            try:
                arrays, meta = self._load_step(s)
                chosen = s
                break
            except CheckpointCorruptError as e:
                if explicit:
                    raise
                logger.warning(
                    "checkpoint step_%010d failed validation (%s) — "
                    "falling back to the previous committed step", s, e)
                last_err = e
        if chosen is None:
            raise CheckpointCorruptError(
                f"no restorable checkpoint in {self.directory}: every "
                f"committed step failed validation") from last_err
        fmt = meta.get("format") or {}
        saved_overlap = fmt.get("overlap")
        if expected_overlap is not None and saved_overlap is not None \
                and saved_overlap != expected_overlap:
            logger.warning(
                "checkpoint step_%010d was written under HetConfig."
                "overlap='%s' but is being restored into overlap='%s' "
                "— optimizer state will be repacked through the flat "
                "stream (bit-exact; see checkpoint/repack.py)",
                chosen, saved_overlap, expected_overlap)
        arrays = repack.adapt_arrays(arrays, template, meta.get("format"))
        return _unflatten_like(template, arrays, allow_cast=allow_cast), \
            meta
