"""M7 — full-state checkpointing (async, atomic, rotated, repackable).

The paper's checkpoint carries: model parameters, completed epochs,
completed steps, optimizer + LR-scheduler state, and the RNG seed. Ours
additionally persists the capacity plan (as structured JSON that
round-trips into a real ``CapacityPlan``) and the data-stream position
(epoch + batches consumed within it) so an elastic restart with a
*different* mesh resumes the identical global sample stream
(core/elastic.py invariant).

On-disk layout (version 2): ``<dir>/step_<N>/``

  arrays.npz   every pytree leaf, keyed by its escaped ``/``-joined
               path (repack.path_key: components percent-escape ``%``
               and ``/``, attribute/index keys map to bare name/index;
               collisions raise at save time)
  meta.json    step / epoch / seed / structured plan / data-stream
               position, plus a ``"format"`` block: format version,
               which TrainState fields were saved packed
               (``overlap="buckets"`` stores AdamW/LAMB moments as one
               (num_buckets, bucket_elems) stack), and the versioned
               ``BucketLayout`` record + fingerprint describing that
               grid (core/buckets.py::layout_record)
  _DONE        commit marker, written into the temp dir before the
               atomic rename — a crash at ANY point leaves either a
               committed ``step_<N>`` or an ignorable ``.tmp``

Repack-on-restore: ``restore`` hands the loaded arrays through
``repack.adapt_arrays`` before unflattening, so a checkpoint written
under any layout (packed moments of any bucket grid, pytree moments,
flat or per-leaf error state, any reduction rank count) restores into
whatever layout the caller's template expects — packed<->pytree and
grid-to-grid translations go through the layout-invariant flat stream
and are bit-exact (see checkpoint/repack.py for the one documented
exception: per-rank error-feedback residuals across a rank-count
change, where only their sum is conserved).

Async: ``save`` snapshots device arrays to host (blocking, cheap), then
writes files on a background thread — the train loop never waits on
disk. On real multi-host deployments only process 0 writes (the paper's
master-process rule); sharded arrays are fully gathered here since CPU
dry-run params are process-local (noted in DESIGN.md §deviations).
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import repack
from repro.core.capacity import CapacityPlan, plan_from_record, plan_record

_DONE = "_DONE"
_PLAN_TAG = "__capacity_plan__"

logger = logging.getLogger(__name__)


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v)
            for k, v in repack.flatten_with_paths(tree).items()}


def _unflatten_like(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = repack.path_key(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def _json_default(obj: Any) -> Any:
    """Structured meta serialization — never silently stringify.

    ``CapacityPlan`` becomes a tagged record that ``_meta_hook``
    rebuilds into a real plan on load; numpy scalars/arrays become
    plain JSON numbers/lists. Anything else raises loudly at save time
    (surfaced by ``wait()``) instead of burying a useless ``str()`` in
    meta.json.
    """
    if isinstance(obj, CapacityPlan):
        return {_PLAN_TAG: plan_record(obj)}
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, jax.Array):
        return np.asarray(obj).tolist()
    raise TypeError(
        f"checkpoint meta value of type {type(obj).__name__!r} is not "
        f"JSON-serializable — give it a structured record (see "
        f"plan_record) instead of relying on str()")


def _meta_hook(d: Dict) -> Any:
    if set(d) == {_PLAN_TAG}:
        return plan_from_record(d[_PLAN_TAG])
    return d


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: List[BaseException] = []

    # ---- save ------------------------------------------------------------

    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot now, write in the background (one writer at a time)."""
        self.wait()                       # at most one in-flight write
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        flat = _flatten_with_paths(host_state)   # key collisions raise HERE
        meta = dict(meta or {})
        meta["step"] = int(step)
        meta.setdefault("format", {"version": repack.FORMAT_VERSION})

        def write():
            try:
                self._write(step, flat, meta)
                self._rotate()
            except BaseException as e:     # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=write, daemon=True,
                                        name=f"ckpt-write-{step}")
        self._thread.start()
        if block:
            self.wait()

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               meta: Dict) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as fh:
            json.dump(meta, fh, indent=1, default=_json_default)
        with open(os.path.join(tmp, _DONE), "w") as fh:
            fh.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- load ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(path, _DONE))):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                expected_overlap: Optional[str] = None
                ) -> Tuple[Any, Dict]:
        """Returns (state shaped like ``template``, meta).

        The template may be differently *sharded* than at save time
        (elastic re-mesh) — placement is the caller's (device_put) —
        and may expect a different optimizer-state LAYOUT than was
        saved: packed moments of any bucket grid, pytree moments, and
        flat/per-leaf error state all translate through
        ``repack.adapt_arrays`` (bit-exact, see checkpoint/repack.py).
        Template leaves only need ``.shape``/``.dtype`` —
        ShapeDtypeStructs work.

        ``expected_overlap``: the restoring config's
        ``HetConfig.overlap`` mode. The checkpoint records which mode
        wrote it (``meta["format"]["overlap"]``); a mismatch still
        restores — the repack handles the layout translation — but is
        LOGGED, never silently adapted, because a packed->pytree (or
        reverse) translation is a real layout change the operator
        should see.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        if not os.path.exists(os.path.join(path, _DONE)):
            raise FileNotFoundError(f"checkpoint {path} incomplete")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as fh:
            meta = json.load(fh, object_hook=_meta_hook)
        fmt = meta.get("format") or {}
        saved_overlap = fmt.get("overlap")
        if expected_overlap is not None and saved_overlap is not None \
                and saved_overlap != expected_overlap:
            logger.warning(
                "checkpoint step_%010d was written under HetConfig."
                "overlap='%s' but is being restored into overlap='%s' "
                "— optimizer state will be repacked through the flat "
                "stream (bit-exact; see checkpoint/repack.py)",
                step, saved_overlap, expected_overlap)
        arrays = repack.adapt_arrays(arrays, template, meta.get("format"))
        return _unflatten_like(template, arrays), meta
