"""M7 — full-state checkpointing (async, atomic, rotated).

The paper's checkpoint carries: model parameters, completed epochs,
completed steps, optimizer + LR-scheduler state, and the RNG seed. Ours
additionally persists the capacity plan and the data-stream position so
an elastic restart with a *different* mesh resumes the identical global
sample stream (core/elastic.py invariant).

Layout: <dir>/step_<N>/
  arrays.npz     every pytree leaf, keyed by flattened path
  meta.json      step/epoch/seed/plan/treedef fingerprint
  _DONE          commit marker (written last -> crash-atomic)

Async: ``save`` snapshots device arrays to host (blocking, cheap), then
writes files on a background thread — the train loop never waits on
disk. On real multi-host deployments only process 0 writes (the paper's
master-process rule); sharded arrays are fully gathered here since CPU
dry-run params are process-local (noted in DESIGN.md §deviations).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_DONE = "_DONE"


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: List[BaseException] = []

    # ---- save ------------------------------------------------------------

    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot now, write in the background (one writer at a time)."""
        self.wait()                       # at most one in-flight write
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        meta = dict(meta or {})
        meta["step"] = int(step)

        def write():
            try:
                self._write(step, host_state, meta)
                self._rotate()
            except BaseException as e:     # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=write, daemon=True,
                                        name=f"ckpt-write-{step}")
        self._thread.start()
        if block:
            self.wait()

    def _write(self, step: int, state: Any, meta: Dict) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **_flatten_with_paths(state))
        with open(os.path.join(tmp, "meta.json"), "w") as fh:
            json.dump(meta, fh, indent=1, default=str)
        with open(os.path.join(tmp, _DONE), "w") as fh:
            fh.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- load ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(path, _DONE))):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        """Returns (state shaped like ``template``, meta). The template
        may be differently *sharded* than at save time (elastic re-mesh)
        — shapes must match, placement is the caller's (device_put)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        if not os.path.exists(os.path.join(path, _DONE)):
            raise FileNotFoundError(f"checkpoint {path} incomplete")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as fh:
            meta = json.load(fh)
        return _unflatten_like(template, arrays), meta
