"""Layout-portable checkpoint repack (overlap mode <-> anything).

Since the overlapped bucket pipeline landed, ``HetConfig.overlap=
"buckets"`` stores the AdamW/LAMB moments packed as ONE
``(num_buckets, bucket_elems)`` f32 stack whose grid is a pure function
of ``(param tree, bucket_mb, reduction ranks, quantization block)``. A
checkpoint written that way could previously only be restored into the
*identical* grid: a different ``bucket_mb``, a re-meshed pod count
(different ``multiple_of``), or a non-overlap run (pytree moments) all
change the expected shapes — and the elastic re-mesh story (HetSeq's
core claim: resume the identical trajectory on new hardware) did not
survive the overlap fast path.

This module makes the checkpoint layout-portable. The key observation:
the packed stack is just the *flat stream* (every leaf raveled and
concatenated in pytree-flatten order) zero-padded and reshaped, and the
stream is layout-invariant. Every translation goes through it::

  packed(A)  -> stream -> packed(B)   re-grid (bucket_mb / re-mesh)
  packed(A)  -> stream -> per-leaf    overlap -> non-overlap resume
  per-leaf   -> stream -> packed(B)   non-overlap -> overlap resume

All three are bit-exact: packing is a reshape + zero-pad, and the
padded tail is zero on every reachable training state (moments start
zero, bucket padding receives zero gradient, the decay mask zeroes the
padding update), which :func:`fit_stream` verifies before trimming.

Error-feedback state (``TrainState.err``, one residual stack per
reduction rank) repacks the same way per rank when the rank count is
unchanged. Across a rank-count change the per-rank residuals have no
exact image (the ranks that produced them no longer exist); the total
outstanding residual is what re-enters future gradients, so the rank
streams are summed and the sum is partitioned element-wise into the
destination ranks' contiguous stream extents — the conserved quantity
survives bit-exactly AND stays distributed (no rank parked with the
whole residual; the per-rank split itself is not recoverable —
documented trade; fp32 runs without error feedback repack bit-exactly
in every direction).

``adapt_arrays`` is the entry point: it rewrites the flattened
``{path-key: array}`` dict loaded from ``arrays.npz`` so it matches the
caller's template, using the versioned layout record saved in
``meta.json`` (``checkpoint.CheckpointManager`` calls it inside
``restore``, so every restore is layout-portable automatically).

Path keys: checkpoints address leaves by ``"/"``-joined key paths.
Components are percent-escaped (``%`` -> ``%25``, ``/`` -> ``%2F``) so
dict keys containing ``/`` cannot collide with nested paths, and
attribute/index key types map to their bare name/index
(``TrainState.opt.m`` -> ``"opt/m"``). :func:`flatten_with_paths`
raises at save time if two leaves ever land on the same key.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np
from jax import tree_util as jtu

# Bump when the on-disk layout / the format block in meta.json changes
# incompatibly. Version 1 = unescaped ad-hoc keys, stringified meta
# (pre-repack); version 2 = escaped keys + structured meta + layout
# records, one gathered arrays.npz; version 3 = per-host shard files
# (arrays_host<k>.npz) + a crash-consistent, checksummed manifest.json
# (checkpoint/checkpoint.py). Version 2 checkpoints still load; the
# array key scheme is unchanged since version 2.
FORMAT_VERSION = 3

MOMENT_GROUPS = ("opt/m", "opt/v")
ERR_GROUP = "err"
PARAMS_PREFIX = "params/"


# --------------------------------------------------------------------------
# path keys
# --------------------------------------------------------------------------


def _escape(component: str) -> str:
    """Injective escaping: no raw '/' survives, so joined keys decode
    uniquely back into components."""
    return component.replace("%", "%25").replace("/", "%2F")


def path_component(entry: Any) -> str:
    if isinstance(entry, jtu.DictKey):
        return _escape(str(entry.key))
    if isinstance(entry, jtu.GetAttrKey):
        return _escape(entry.name)
    if isinstance(entry, jtu.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jtu.FlattenedIndexKey):
        return str(entry.key)
    return _escape(str(entry))


def path_key(path: Sequence[Any]) -> str:
    return "/".join(path_component(p) for p in path)


def flatten_with_paths(tree: Any) -> Dict[str, Any]:
    """Ordered ``{escaped key path: leaf}`` — raises on collision.

    Collisions cannot arise from dict keys containing ``/`` (escaped)
    or from mixed key types at one node (a node is exactly one
    container type); the check guards custom pytree key types whose
    ``str()`` is ambiguous.
    """
    out: Dict[str, Any] = {}
    for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
        key = path_key(path)
        if key in out:
            raise ValueError(
                f"checkpoint path key collision: two leaves flatten to "
                f"'{key}' — register distinct key types for this pytree")
        out[key] = leaf
    return out


# --------------------------------------------------------------------------
# the flat stream
# --------------------------------------------------------------------------


def fit_stream(stream: np.ndarray, n: int, what: str = "state"
               ) -> np.ndarray:
    """Return the stream resized to exactly ``n`` elements.

    Growing pads with zeros (new grid has more padding); shrinking
    verifies the dropped tail is all-zero — nonzero data past the
    target length means the checkpoint does not actually fit the target
    layout (corrupt file or wrong model) and raises.
    """
    flat = np.asarray(stream).reshape(-1)
    if flat.size == n:
        return flat
    if flat.size < n:
        out = np.zeros(n, flat.dtype)
        out[:flat.size] = flat
        return out
    if np.any(flat[n:]):
        raise ValueError(
            f"cannot repack '{what}': checkpoint holds nonzero data past "
            f"element {n} ({flat.size} saved) — the saved state does not "
            f"fit the target layout")
    return flat[:n]


def _sizes(shapes: Sequence[Sequence[int]]) -> List[int]:
    return [int(np.prod(s)) if len(s) else 1 for s in shapes]


# --------------------------------------------------------------------------
# group translation
# --------------------------------------------------------------------------


def _group_leaf_order(template: Dict[str, Any], saved_keys: List[str],
                      group: str) -> List[str]:
    """Stream order for a per-leaf group being packed.

    Moment/err trees mirror the params tree, so the canonical order is
    the template's ``params/`` flatten order transplanted onto the
    group prefix. When the template has no params mirror (bare-dict
    states in tests), fall back to the saved insertion order — which is
    the save-time flatten order of the same treedef.
    """
    subpaths = [k[len(PARAMS_PREFIX):] for k in template
                if k.startswith(PARAMS_PREFIX)]
    expected = [f"{group}/{s}" for s in subpaths]
    if subpaths and set(expected) == set(saved_keys):
        return expected
    return saved_keys


def _adapt_group(arrays: Dict[str, np.ndarray], template: Dict[str, Any],
                 group: str, record: Optional[Dict]) -> None:
    """Translate one moment group in place to the template's form."""
    saved_packed = group in arrays
    tpl_packed = group in template
    tpl_sub = [k for k in template if k.startswith(group + "/")]
    saved_sub = [k for k in arrays if k.startswith(group + "/")]

    if saved_packed and tpl_packed:
        tgt = tuple(int(d) for d in template[group].shape)
        if tuple(arrays[group].shape) == tgt:
            return
        if len(tgt) != 2:
            raise ValueError(
                f"packed group '{group}' restores into rank-{len(tgt)} "
                f"template leaf; expected (num_buckets, bucket_elems)")
        stream = np.asarray(arrays[group]).reshape(-1)
        if record is not None:
            # strict trim through the recorded true (pre-padding) total
            stream = fit_stream(stream, int(record["total"]), group)
        arrays[group] = fit_stream(stream, tgt[0] * tgt[1],
                                   group).reshape(tgt)
    elif saved_packed and not tpl_packed:
        if not tpl_sub:
            return                     # template holds no such group
        sizes = _sizes([template[k].shape for k in tpl_sub])
        total = sum(sizes)
        if record is not None and int(record["total"]) != total:
            raise ValueError(
                f"layout mismatch unpacking '{group}': checkpoint stream "
                f"holds {record['total']} elements, template pytree "
                f"expects {total} (fingerprint "
                f"{record.get('fingerprint', '?')})")
        stream = fit_stream(arrays.pop(group), total, group)
        off = 0
        for key, n in zip(tpl_sub, sizes):
            arrays[key] = stream[off:off + n].reshape(template[key].shape)
            off += n
    elif not saved_packed and tpl_packed:
        if not saved_sub:
            return                     # nothing saved -> missing-leaf error
        order = _group_leaf_order(template, saved_sub, group)
        stream = np.concatenate(
            [np.asarray(arrays.pop(k)).reshape(-1) for k in order])
        nb, be = (int(d) for d in template[group].shape)
        arrays[group] = fit_stream(stream, nb * be, group).reshape(nb, be)


def _redistribute_ranks(streams: np.ndarray, target_ranks: int
                        ) -> np.ndarray:
    """(ranks, n) residual streams -> (target_ranks, n).

    Same rank count: identity (bit-exact). Different: the per-rank
    residuals have no exact image (the producing ranks are gone), so
    the conserved quantity is their SUM — the total outstanding
    residual that re-enters future gradients. The sum is partitioned
    element-wise into the destination ranks' contiguous stream extents:
    rank ``r`` carries the summed residual over its extent and zero
    elsewhere, so every element lands on exactly one rank (the total is
    conserved bit-exactly) and the compression state stays DISTRIBUTED.
    The old behavior parked the whole sum on rank 0, which skewed rank
    0's quantization scales on the first int8 exchanges after a re-mesh
    resume while every other rank restarted from zero residual.
    """
    from repro.core.buckets import host_shard_extents

    ranks = streams.shape[0]
    if ranks == target_ranks:
        return streams
    total = streams.sum(axis=0)
    out = np.zeros((target_ranks, streams.shape[1]), streams.dtype)
    for r, (lo, hi) in enumerate(host_shard_extents(streams.shape[1],
                                                    target_ranks)):
        out[r, lo:hi] = total[lo:hi]
    return out


def _adapt_err(arrays: Dict[str, np.ndarray],
               template: Dict[str, Any]) -> None:
    """Translate the error-feedback group to the template's form.

    Handles flat (ranks, num_buckets, bucket_elems) stacks, legacy
    per-leaf (ranks, *leaf) mirrors, and absence on either side (a
    checkpoint without residual state restores into an error-feedback
    config with FRESH zero residuals; a target without error feedback
    ignores saved residuals).
    """
    tpl_flat = ERR_GROUP in template
    tpl_sub = [k for k in template if k.startswith(ERR_GROUP + "/")]
    if not tpl_flat and not tpl_sub:
        return
    saved_flat = ERR_GROUP in arrays
    saved_sub = [k for k in arrays if k.startswith(ERR_GROUP + "/")]

    streams: Optional[np.ndarray] = None
    if saved_flat:
        a = np.asarray(arrays.pop(ERR_GROUP))
        streams = a.reshape(a.shape[0], -1)
    elif saved_sub:
        order = _group_leaf_order(template, saved_sub, ERR_GROUP)
        per_leaf = [np.asarray(arrays.pop(k)) for k in order]
        ranks = per_leaf[0].shape[0]
        streams = np.concatenate(
            [a.reshape(ranks, -1) for a in per_leaf], axis=1)

    if tpl_flat:
        ranks_t, nb, be = (int(d) for d in template[ERR_GROUP].shape)
        if streams is None:
            arrays[ERR_GROUP] = np.zeros((ranks_t, nb, be), np.float32)
            return
        streams = _redistribute_ranks(streams, ranks_t)
        arrays[ERR_GROUP] = np.stack(
            [fit_stream(s, nb * be, ERR_GROUP) for s in streams]
        ).reshape(ranks_t, nb, be)
    else:
        ranks_t = int(template[tpl_sub[0]].shape[0])
        shapes = [tuple(int(d) for d in template[k].shape[1:])
                  for k in tpl_sub]
        sizes = _sizes(shapes)
        total = sum(sizes)
        if streams is None:
            for key in tpl_sub:
                arrays[key] = np.zeros(template[key].shape, np.float32)
            return
        streams = _redistribute_ranks(streams, ranks_t)
        fitted = np.stack([fit_stream(s, total, ERR_GROUP)
                           for s in streams])
        off = 0
        for key, n, shape in zip(tpl_sub, sizes, shapes):
            arrays[key] = fitted[:, off:off + n].reshape(
                (ranks_t,) + shape)
            off += n


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def adapt_arrays(arrays: Dict[str, np.ndarray], template: Any,
                 fmt: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """Rewrite a loaded ``{path key: array}`` dict to fit ``template``.

    ``template`` is the state pytree the caller wants back (real arrays
    or ShapeDtypeStructs — only ``.shape`` is read). ``fmt`` is the
    ``"format"`` block from ``meta.json`` (may be None for bare saves
    that passed no format meta): it carries the format version, which
    fields were saved packed, and the versioned layout record used for
    strict total/fingerprint validation. Translation itself is
    structural — the flat stream is canonical — so format-less
    checkpoints written by THIS key scheme still repack; the record
    only tightens the error checking. Checkpoints from builds predating
    the escaped key scheme (format version < 2, ad-hoc ``str()`` keys)
    are not readable — no deployment persisted any, so no v1 key
    translation is carried.
    """
    fmt = fmt or {}
    version = fmt.get("version")
    if version is not None and int(version) > FORMAT_VERSION:
        raise ValueError(
            f"checkpoint format version {version} is newer than this "
            f"build supports ({FORMAT_VERSION})")
    if fmt.get("pipeline") is not None:
        # stage partition that wrote the checkpoint: params are stored
        # per-leaf so NO translation is needed across stage plans, but
        # a malformed record means the writer was broken — fail the
        # restore loudly instead of resuming from a suspect checkpoint
        from repro.core import pipeline as _pipe

        _pipe.stage_from_record(fmt["pipeline"])
    record = fmt.get("layout") or None

    template_flat = flatten_with_paths(template)
    out = dict(arrays)
    groups = list(MOMENT_GROUPS)
    for g in fmt.get("packed_fields") or ():
        if g not in groups and g != ERR_GROUP:
            groups.append(g)
    for g in groups:
        _adapt_group(out, template_flat, g, record)
    _adapt_err(out, template_flat)
    return out
