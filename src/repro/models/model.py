"""Model facade: build_model(cfg) -> init / loss / prefill / decode.

The training loss follows the HetSeq aggregation contract (paper M1/M3):
every token carries a weight (0 for dummy/padding tokens); ``loss_fn``
returns the *weighted loss sum* and the *weight sum* — never a local
mean — so any split of the batch across heterogeneous workers aggregates
to exactly the single-process loss. Gradient accumulation and the DP
reduction both divide by the summed weight once, at the end
(core/accumulate.py, launch/steps.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.cross_entropy import ops as ce_ops
from repro.models import transformer as tr
from repro.models.blocks import LOCAL_CTX, ParallelCtx


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(
        functools.partial(tr.init_params, cfg), jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe.enabled:
        mo = cfg.moe
        per_expert = 3 * cfg.d_model * mo.expert_d_ff
        total -= cfg.num_layers * (mo.num_experts - mo.top_k) * per_expert
    return total


@dataclasses.dataclass(frozen=True)
class Model:
    """Bundle of pure functions over a fixed config."""

    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    loss_fn: Callable[..., Tuple[jnp.ndarray, jnp.ndarray, Dict]]
    logits_fn: Callable[..., jnp.ndarray]
    prefill: Callable[..., Tuple[jnp.ndarray, Any]]
    decode: Callable[..., Tuple[jnp.ndarray, Any]]
    init_cache: Callable[..., Any]
    # paged serving path (continuous batching, repro.serve)
    prefill_paged: Callable[..., Tuple[jnp.ndarray, Any]]
    decode_paged: Callable[..., Tuple[jnp.ndarray, Any]]
    init_paged_cache: Callable[..., Any]


def build_model(cfg: ModelConfig) -> Model:
    def init_params(key):
        return tr.init_params(cfg, key)

    def loss_fn(params, batch: Dict[str, jnp.ndarray],
                ctx: ParallelCtx = LOCAL_CTX,
                ce_impl: str = "reference",
                label_smoothing: Optional[float] = None):
        """batch: inputs (B,S)[int] or (B,S,d)[stub], labels (B,S) int32,
        weights (B,S) f32 (0 => dummy token, paper M3).

        ``label_smoothing``: static CE smoothing factor (the train step
        passes ``TrainConfig.label_smoothing``); None falls back to a
        float ``batch["label_smoothing"]`` entry if present, else 0.0.

        Returns (objective_sum, weight_sum, metrics). objective_sum is
        differentiable; divide by (globally summed) weight_sum once.
        """
        if label_smoothing is None:
            from_batch = batch.get("label_smoothing", 0.0)
            label_smoothing = (from_batch
                               if isinstance(from_batch, float) else 0.0)
        x = tr.embed_tokens(params, batch["inputs"], cfg, ctx)
        hidden, aux = tr.hidden_states(params, x, cfg, ctx)
        b, s, d = hidden.shape
        lm_w = tr.lm_head_matrix(params, cfg)
        loss_sum, w_sum = ce_ops.weighted_cross_entropy(
            hidden.reshape(b * s, d), lm_w,
            batch["labels"].reshape(-1).astype(jnp.int32),
            batch["weights"].reshape(-1).astype(jnp.float32),
            label_smoothing=label_smoothing,
            logit_softcap=cfg.logit_softcap,
            impl=ce_impl)
        # fold the MoE aux loss in as a per-token penalty so that
        # objective_sum / weight_sum == ce_mean + aux (accumulation-exact)
        objective_sum = loss_sum + aux * jax.lax.stop_gradient(w_sum)
        metrics = {"ce_sum": loss_sum, "aux": aux}
        return objective_sum, w_sum, metrics

    def logits_fn(params, inputs, ctx: ParallelCtx = LOCAL_CTX):
        x = tr.embed_tokens(params, inputs, cfg, ctx)
        hidden, _ = tr.hidden_states(params, x, cfg, ctx)
        return tr.unembed(params, hidden, cfg, ctx)

    def prefill(params, inputs, ctx: ParallelCtx = LOCAL_CTX,
                max_len: Optional[int] = None):
        """Returns (next-token logits (B, V), cache)."""
        s = inputs.shape[1]
        max_len = max_len or s
        x = tr.embed_tokens(params, inputs, cfg, ctx)
        hidden, cache = tr.prefill(params, x, cfg, ctx, max_len)
        logits = tr.unembed(params, hidden[:, -1:, :], cfg, ctx)[:, 0, :]
        return logits, cache

    def decode(params, inputs, cache, pos, ctx: ParallelCtx = LOCAL_CTX):
        """inputs: token ids (B,) or stub embeds (B, d). pos: scalar int."""
        if cfg.frontend == "token":
            x = tr.embed_tokens(params, inputs[:, None], cfg, ctx)
        else:
            x = tr.embed_tokens(params, inputs[:, None, :], cfg, ctx)
        hidden, cache = tr.decode_step(params, x, cfg, ctx, cache, pos)
        logits = tr.unembed(params, hidden, cfg, ctx)[:, 0, :]
        return logits, cache

    def init_cache(batch: int, max_len: int):
        return tr.init_cache(cfg, batch, max_len)

    def prefill_paged(params, inputs, lens, paged_cache, block_tables,
                      ctx: ParallelCtx = LOCAL_CTX):
        """Prefill a length-bucketed chunk into the paged pool.

        inputs (B, S) token ids padded to the bucket length S (a
        multiple of the block size); lens (B,) real prompt lengths;
        block_tables (B, MB). Returns (per-sequence next-token logits
        (B, V) taken at each sequence's own last real token, updated
        paged cache).
        """
        from repro.models import kvcache as kvc
        s = inputs.shape[1]
        x = tr.embed_tokens(params, inputs, cfg, ctx)
        hidden, contiguous = tr.prefill(params, x, cfg, ctx, s)
        last = jnp.clip(lens - 1, 0, s - 1)[:, None, None]
        h_last = jnp.take_along_axis(hidden, last, axis=1)
        logits = tr.unembed(params, h_last, cfg, ctx)[:, 0, :]
        cache = kvc.write_prefill_blocks(paged_cache, contiguous,
                                         block_tables)
        return logits, cache

    def decode_paged(params, inputs, paged_cache, block_tables, kv_lens,
                     ctx: ParallelCtx = LOCAL_CTX):
        """inputs: token ids (B,); kv_lens (B,) per-sequence depths."""
        if cfg.frontend != "token":
            raise ValueError("paged decode supports the token frontend "
                             f"only, got {cfg.frontend!r}")
        x = tr.embed_tokens(params, inputs[:, None], cfg, ctx)
        hidden, cache = tr.decode_step_paged(params, x, cfg, ctx,
                                             paged_cache, block_tables,
                                             kv_lens)
        logits = tr.unembed(params, hidden, cfg, ctx)[:, 0, :]
        return logits, cache

    def init_paged_cache(layout):
        return tr.init_paged_cache(cfg, layout)

    return Model(cfg=cfg, init_params=init_params, loss_fn=loss_fn,
                 logits_fn=logits_fn, prefill=prefill, decode=decode,
                 init_cache=init_cache, prefill_paged=prefill_paged,
                 decode_paged=decode_paged,
                 init_paged_cache=init_paged_cache)
