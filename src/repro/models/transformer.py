"""Composable decoder stack covering all assigned architecture families.

Stack plans (derived from the config):
  uniform — dense / moe / mla archs: one scanned stack of identical
            (attention, ffn) layers. ``lax.scan`` over stacked params with
            a configurable remat policy.
  zamba   — Mamba2 backbone; a single weight-shared attention block is
            applied after every ``hybrid.attn_every`` mamba layers
            (outer scan over groups, inner scan over mamba layers).
  xlstm   — alternating mLSTM / sLSTM blocks, scanned over pairs.

Public API (used by model.py):
  init_params(cfg, key)                         -> params pytree
  hidden_states(params, embeds, cfg, ctx)       -> (hidden, aux_loss)
  prefill(params, embeds, cfg, ctx, max_len)    -> (hidden, cache)
  decode_step(params, embeds, cfg, ctx, cache, pos) -> (hidden, cache)
  embed_tokens / unembed
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import xlstm as xl
from repro.models.blocks import (LOCAL_CTX, ParallelCtx, _cast, apply_norm,
                                 attention_block, batch_spec, constrain,
                                 dense_init, embed_init, init_attention,
                                 init_mla, init_mlp, init_moe, init_norm,
                                 mla_block, mlp_block, moe_block)
from repro.models.kvcache import (PagedLayout, attention_decode,
                                  attention_decode_paged, init_gqa_cache,
                                  init_gqa_paged_cache, init_mla_cache,
                                  init_mla_paged_cache, mla_decode,
                                  mla_decode_paged)
from repro.models.ssm import (init_mamba, mamba_block, mamba_decode_step,
                              mamba_dims)


def stack_plan(cfg: ModelConfig) -> str:
    if cfg.xlstm.enabled:
        return "xlstm"
    if cfg.hybrid.enabled:
        return "zamba"
    if cfg.ssm.enabled:
        return "mamba"
    return "uniform"


def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)      # "full"


# --------------------------------------------------------------------------
# uniform layer (dense / moe / mla)
# --------------------------------------------------------------------------


def _attn_init(cfg: ModelConfig, key):
    return init_mla(cfg, key) if cfg.mla.enabled else init_attention(cfg, key)


def init_uniform_layer(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "ln1": init_norm(cfg, ks[0]),
        "attn": _attn_init(cfg, ks[1]),
        "ln2": init_norm(cfg, ks[2]),
    }
    if cfg.moe.enabled:
        p["moe"] = init_moe(cfg, ks[3])
        if cfg.moe.dense_residual:
            p["dense"] = init_mlp(cfg, ks[4], d_ff=cfg.d_ff)
    else:
        p["mlp"] = init_mlp(cfg, ks[3])
    return p


def apply_uniform_layer(p, x: jnp.ndarray, cfg: ModelConfig,
                        ctx: ParallelCtx, positions: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.mla.enabled:
        a = mla_block(p["attn"], h, cfg, ctx, positions)
    else:
        a = attention_block(p["attn"], h, cfg, ctx, positions)
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = moe_block(p["moe"], h2, cfg, ctx)
        if "dense" in p:
            m = m + mlp_block(p["dense"], h2, cfg, ctx)
    else:
        m = mlp_block(p["mlp"], h2, cfg, ctx)
    return x + m, aux


def apply_uniform_layer_prefill(p, x, cfg, ctx, positions):
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.mla.enabled:
        a, kv = mla_block(p["attn"], h, cfg, ctx, positions, return_kv=True)
    else:
        a, kv = attention_block(p["attn"], h, cfg, ctx, positions,
                                return_kv=True)
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_block(p["moe"], h2, cfg, ctx, train=False)
        if "dense" in p:
            m = m + mlp_block(p["dense"], h2, cfg, ctx)
    else:
        m = mlp_block(p["mlp"], h2, cfg, ctx)
    return x + m, kv


def apply_uniform_layer_decode(p, x, cfg, ctx, cache_l, pos):
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.mla.enabled:
        a, new_cache = mla_decode(p["attn"], h, cfg, ctx,
                                  cache_l["c_kv"], cache_l["k_rope"], pos)
        new_cache = {"c_kv": new_cache[0], "k_rope": new_cache[1]}
    else:
        a, new_cache = attention_decode(p["attn"], h, cfg, ctx,
                                        cache_l["k"], cache_l["v"], pos)
        new_cache = {"k": new_cache[0], "v": new_cache[1]}
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_block(p["moe"], h2, cfg, ctx, train=False)
        if "dense" in p:
            m = m + mlp_block(p["dense"], h2, cfg, ctx)
    else:
        m = mlp_block(p["mlp"], h2, cfg, ctx)
    return x + m, new_cache


def apply_uniform_layer_decode_paged(p, x, cfg, ctx, cache_l,
                                     block_tables, kv_lens):
    """Paged twin of apply_uniform_layer_decode: per-layer pool caches
    (N, bs, ...) addressed through per-sequence block tables + kv_lens
    instead of a contiguous (B, S_max, ...) slab and a scalar pos."""
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.mla.enabled:
        a, new_cache = mla_decode_paged(p["attn"], h, cfg, ctx,
                                        cache_l["c_kv"], cache_l["k_rope"],
                                        block_tables, kv_lens)
        new_cache = {"c_kv": new_cache[0], "k_rope": new_cache[1]}
    else:
        a, new_cache = attention_decode_paged(p["attn"], h, cfg, ctx,
                                              cache_l["k"], cache_l["v"],
                                              block_tables, kv_lens)
        new_cache = {"k": new_cache[0], "v": new_cache[1]}
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_block(p["moe"], h2, cfg, ctx, train=False)
        if "dense" in p:
            m = m + mlp_block(p["dense"], h2, cfg, ctx)
    else:
        m = mlp_block(p["mlp"], h2, cfg, ctx)
    return x + m, new_cache


# --------------------------------------------------------------------------
# zamba layers (mamba backbone + shared attention block)
# --------------------------------------------------------------------------


def init_mamba_layer(cfg: ModelConfig, key) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {"ln": init_norm(cfg, k1), "mamba": init_mamba(cfg, k2)}


def init_shared_attn(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg, ks[0]), "attn": init_attention(cfg, ks[1])}
    if cfg.hybrid.shared_attn_d_ff > 0:
        p["ln2"] = init_norm(cfg, ks[2])
        p["mlp"] = init_mlp(cfg, ks[3], d_ff=cfg.hybrid.shared_attn_d_ff)
    return p


def _apply_shared_attn(p, x, cfg, ctx, positions):
    h = apply_norm(p["ln1"], x, cfg)
    x = x + attention_block(p["attn"], h, cfg, ctx, positions)
    if "mlp" in p:
        h2 = apply_norm(p["ln2"], x, cfg)
        x = x + mlp_block(p["mlp"], h2, cfg, ctx)
    return x


def _apply_shared_attn_prefill(p, x, cfg, ctx, positions):
    h = apply_norm(p["ln1"], x, cfg)
    a, kv = attention_block(p["attn"], h, cfg, ctx, positions,
                            return_kv=True)
    x = x + a
    if "mlp" in p:
        h2 = apply_norm(p["ln2"], x, cfg)
        x = x + mlp_block(p["mlp"], h2, cfg, ctx)
    return x, kv


def _apply_shared_attn_decode(p, x, cfg, ctx, k_cache, v_cache, pos):
    h = apply_norm(p["ln1"], x, cfg)
    a, (k_cache, v_cache) = attention_decode(p["attn"], h, cfg, ctx,
                                             k_cache, v_cache, pos)
    x = x + a
    if "mlp" in p:
        h2 = apply_norm(p["ln2"], x, cfg)
        x = x + mlp_block(p["mlp"], h2, cfg, ctx)
    return x, (k_cache, v_cache)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    plan = stack_plan(cfg)
    ke, kl, kn, kh, ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {}
    if cfg.frontend == "token":
        params["embed"] = embed_init(ke, (cfg.vocab_size, cfg.d_model), dt)
    params["final_norm"] = init_norm(cfg, kn)
    if not (cfg.tie_embeddings and cfg.frontend == "token"):
        params["lm_head"] = dense_init(
            kh, (cfg.d_model, cfg.vocab_size), dt)

    if plan == "uniform":
        params["layers"] = _stacked_init(
            lambda k: init_uniform_layer(cfg, k), kl, cfg.num_layers)
    elif plan == "mamba":
        params["layers"] = _stacked_init(
            lambda k: init_mamba_layer(cfg, k), kl, cfg.num_layers)
    elif plan == "zamba":
        params["layers"] = _stacked_init(
            lambda k: init_mamba_layer(cfg, k), kl, cfg.num_layers)
        params["shared_attn"] = init_shared_attn(cfg, ks)
    elif plan == "xlstm":
        n_pairs = cfg.num_layers // 2
        k1, k2 = jax.random.split(kl)
        params["mlstm_layers"] = _stacked_init(
            lambda k: {"ln": init_norm(cfg, jax.random.fold_in(k, 0)),
                       "blk": xl.init_mlstm_block(cfg, k)}, k1, n_pairs)
        params["slstm_layers"] = _stacked_init(
            lambda k: {"ln": init_norm(cfg, jax.random.fold_in(k, 0)),
                       "blk": xl.init_slstm_block(cfg, k)}, k2, n_pairs)
    return params


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------


def embed_tokens(params, inputs: jnp.ndarray, cfg: ModelConfig,
                 ctx: ParallelCtx) -> jnp.ndarray:
    """inputs: token ids (B, S) int32 — or, for embedding_stub frontends,
    precomputed frame/patch embeddings (B, S, d_model)."""
    if cfg.frontend == "token":
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    return constrain(x, ctx, batch_spec(ctx, None, None))


def unembed(params, hidden: jnp.ndarray, cfg: ModelConfig,
            ctx: ParallelCtx) -> jnp.ndarray:
    """hidden (..., d) -> logits (..., V)."""
    if cfg.tie_embeddings and cfg.frontend == "token":
        w = _cast(params["embed"], cfg.compute_dtype).T
    else:
        w = _cast(params["lm_head"], cfg.compute_dtype)
    logits = hidden @ w
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, ctx, batch_spec(ctx, None, ctx.tp_axis))


def lm_head_matrix(params, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings and cfg.frontend == "token":
        return _cast(params["embed"], cfg.compute_dtype).T
    return _cast(params["lm_head"], cfg.compute_dtype)


# --------------------------------------------------------------------------
# forward (training / scoring): hidden states, no cache
# --------------------------------------------------------------------------


def hidden_states(params, embeds: jnp.ndarray, cfg: ModelConfig,
                  ctx: ParallelCtx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """embeds (B, S, d) -> (hidden (B, S, d), aux_loss scalar)."""
    plan = stack_plan(cfg)
    b, s, _ = embeds.shape
    positions = jnp.arange(s)

    def sp(x):
        # sequence-parallel residual stream: the layer-scan carry (which
        # full remat saves per layer) shards S over the model axis
        return constrain(x, ctx, batch_spec(ctx, ctx.tp_axis, None))

    x = sp(embeds)
    aux_total = jnp.zeros((), jnp.float32)

    if plan == "uniform":
        def body(carry, lp):
            x, aux = carry
            x2, a = apply_uniform_layer(lp, x, cfg, ctx, positions)
            return (sp(x2), aux + a), None
        body = _remat(body, cfg)
        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["layers"])
        else:
            # unrolled stack (cfg.scan_layers=False): same per-layer
            # body, python loop instead of lax.scan. Required by
            # HetConfig.overlap="backward" — the staged layer-by-layer
            # backward is an unrolled program, and XLA compiles dots
            # inside a scan body differently from top-level dots
            # (last-bit fp differences), so bit-exact overlap needs the
            # monolithic path unrolled too. Costs an L-times-larger HLO.
            for layer in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[layer], params["layers"])
                (x, aux_total), _ = body((x, aux_total), lp)

    elif plan == "mamba":
        def body(carry, lp):
            x2 = carry + mamba_block(
                lp["mamba"], apply_norm(lp["ln"], carry, cfg), cfg, ctx)
            return sp(x2), None
        body = _remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])

    elif plan == "zamba":
        every = cfg.hybrid.attn_every
        groups = cfg.num_layers // every
        gl = jax.tree.map(
            lambda a: a.reshape(groups, every, *a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def group_body(carry, group_params):
            def inner(xc, lp):
                return xc + mamba_block(
                    lp["mamba"], apply_norm(lp["ln"], xc, cfg), cfg, ctx
                ), None
            xg, _ = jax.lax.scan(inner, carry, group_params)
            xg = _apply_shared_attn(shared, xg, cfg, ctx, positions)
            return sp(xg), None
        group_body = _remat(group_body, cfg)
        x, _ = jax.lax.scan(group_body, x, gl)

    elif plan == "xlstm":
        def pair_body(carry, lp):
            mp, sp_ = lp
            xc = carry + xl.mlstm_block(
                mp["blk"], apply_norm(mp["ln"], carry, cfg), cfg, ctx)
            xc = xc + xl.slstm_block(
                sp_["blk"], apply_norm(sp_["ln"], xc, cfg), cfg, ctx)
            return sp(xc), None
        pair_body = _remat(pair_body, cfg)
        x, _ = jax.lax.scan(pair_body, x,
                            (params["mlstm_layers"], params["slstm_layers"]))

    return apply_norm(params["final_norm"], x, cfg), aux_total


# --------------------------------------------------------------------------
# staged backward segments (HetConfig.overlap="backward")
#
# The backward-overlap pipeline needs gradients layer by layer, so the
# loss is decomposed into VJP-able segments over the uniform block
# stack (a jax.remat-style staged backward: the forward saves only the
# residual-stream carry at every layer boundary, and each segment's
# VJP recomputes its own activations — exactly what jax.checkpoint
# does inside the monolithic scan). Segment math is IDENTICAL to the
# hidden_states/loss_fn path: layer_fn is the scan body, head_fn is
# final-norm + LM head + weighted CE, embed_fn the token embedding, so
# with cfg.scan_layers=False the staged gradients are bit-identical to
# jax.grad of the monolithic objective (asserted by
# tests/test_overlap.py).
#
# Stage numbering (backward completion order): stage 0 = head
# (final_norm, lm_head / tied embed — lands first), stage s in [1, L]
# = layer L-s, stage L+1 = the embedding table (lands last; a tied
# table also receives a head-stage contribution, so its grad is only
# final at L+1). core/buckets.py::bucket_readiness maps these stages
# onto the flat bucket grid.
# --------------------------------------------------------------------------


def supports_staged_backward(cfg: ModelConfig) -> bool:
    """The staged backward covers the uniform stack plan (dense / moe /
    mla); the mamba/zamba/xlstm plans keep the scanned backward."""
    return stack_plan(cfg) == "uniform"


def head_param_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    """Top-level param keys whose grads land at stage 0 (the head)."""
    if cfg.tie_embeddings and cfg.frontend == "token":
        return ("final_norm", "embed")
    return ("final_norm", "lm_head")


def staged_uniform_segments(cfg: ModelConfig, ctx: ParallelCtx, *,
                            label_smoothing: float = 0.0,
                            ce_impl: str = "reference") -> Dict[str, Any]:
    """The VJP-able segment functions of the uniform-stack objective.

    Returns a dict of pure functions (each vmap/vjp-able per DP rank):

      embed_fn(embed_params, inputs)        -> x0 (stage L+1 forward)
      layer_fn(lp, x, positions)            -> (x', aux_l) — the
                                               hidden_states scan body
      head_fn(head_params, x, labels, weights) -> (ce_sum, w_sum)

    The caller composes ``objective = ce_sum + (sum aux_l) *
    stop_grad(w_sum)`` (model.py's aggregation contract) and drives the
    backward newest-stage-first, handing each landed gradient to the
    bucket flush pipeline.
    """
    def sp(x):
        return constrain(x, ctx, batch_spec(ctx, ctx.tp_axis, None))

    def embed_fn(embed_params, inputs):
        return sp(embed_tokens(embed_params, inputs, cfg, ctx))

    def layer_fn(lp, x, positions):
        x2, a = apply_uniform_layer(lp, x, cfg, ctx, positions)
        return sp(x2), a

    def head_fn(head_params, x, labels, weights):
        hidden = apply_norm(head_params["final_norm"], x, cfg)
        b, s, d = hidden.shape
        lm_w = lm_head_matrix(head_params, cfg)
        from repro.kernels.cross_entropy import ops as ce_ops
        return ce_ops.weighted_cross_entropy(
            hidden.reshape(b * s, d), lm_w,
            labels.reshape(-1).astype(jnp.int32),
            weights.reshape(-1).astype(jnp.float32),
            label_smoothing=label_smoothing,
            logit_softcap=cfg.logit_softcap,
            impl=ce_impl)

    return {"embed_fn": embed_fn, "layer_fn": layer_fn,
            "head_fn": head_fn, "head_keys": head_param_keys(cfg)}


def pipeline_stage_fns(cfg: ModelConfig, ctx: ParallelCtx,
                       stage_ranges, *,
                       label_smoothing: float = 0.0,
                       ce_impl: str = "reference") -> Dict[str, Any]:
    """staged_uniform_segments generalized so a segment boundary can be
    a pipeline cut (core/pipeline.py StagePlan).

    ``stage_ranges``: list of (start, stop) contiguous layer ranges
    covering [0, num_layers). Returns the staged_uniform_segments dict
    plus ``stage_fwd``: a list of per-stage VJP-able functions

      stage_fwd[s](layer_slice, x, aux, positions) -> (x', aux')

    where ``layer_slice`` is the params["layers"] pytree sliced to the
    stage's leading-dim range. Each stage is the same per-layer
    ``layer_fn`` chain as the monolithic unrolled stack — aux threads
    through the carry so the cross-stage composition reproduces
    ``hidden_states``'s add order bit-for-bit. The embedding belongs to
    stage 0 (run embed_fn before stage_fwd[0]) and the head to the last
    stage (run head_fn after stage_fwd[-1]) — transformer-side contract
    for ``launch/steps.py::_build_pipeline_step``.
    """
    if stack_plan(cfg) != "uniform":
        raise ValueError(
            f"pipeline stages require the uniform stack plan; "
            f"{cfg.name} uses '{stack_plan(cfg)}'")
    ranges = [(int(a), int(b)) for a, b in stage_ranges]
    covered = 0
    for s, (start, stop) in enumerate(ranges):
        if start != covered or stop <= start:
            raise ValueError(
                f"stage_ranges must tile [0, {cfg.num_layers}) "
                f"contiguously; stage {s} got [{start}, {stop}) after "
                f"{covered} covered layers")
        covered = stop
    if covered != cfg.num_layers:
        raise ValueError(
            f"stage_ranges cover {covered} layers, model has "
            f"{cfg.num_layers}")

    segs = staged_uniform_segments(
        cfg, ctx, label_smoothing=label_smoothing, ce_impl=ce_impl)
    layer_fn = segs["layer_fn"]

    def make_stage(num_layers_s):
        def stage_fwd(layer_slice, x, aux, positions):
            for i in range(num_layers_s):
                lp = jax.tree.map(lambda a: a[i], layer_slice)
                x, a = layer_fn(lp, x, positions)
                aux = aux + a
            return x, aux
        return stage_fwd

    segs["stage_fwd"] = [make_stage(stop - start) for start, stop in ranges]
    segs["stage_ranges"] = ranges
    return segs


# --------------------------------------------------------------------------
# prefill: forward + cache construction
# --------------------------------------------------------------------------


def prefill(params, embeds: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
            max_len: int) -> Tuple[jnp.ndarray, Any]:
    """Returns (hidden (B, S, d), cache). Cache covers max_len positions."""
    plan = stack_plan(cfg)
    b, s, _ = embeds.shape
    positions = jnp.arange(s)
    x = embeds
    pad = max_len - s

    def pad_cache(kv):       # (L?, B, S, ...) -> (..., max_len, ...)
        return jnp.pad(kv, ((0, 0), (0, 0), (0, pad)) +
                       ((0, 0),) * (kv.ndim - 3)) if pad else kv

    if plan == "uniform":
        def body(xc, lp):
            x2, kv = apply_uniform_layer_prefill(lp, xc, cfg, ctx, positions)
            return x2, kv
        x, kvs = jax.lax.scan(body, x, params["layers"])
        if cfg.mla.enabled:
            cache = {"c_kv": pad_cache(kvs[0]), "k_rope": pad_cache(kvs[1])}
        else:
            cache = {"k": pad_cache(kvs[0]), "v": pad_cache(kvs[1])}

    elif plan == "mamba":
        def body(xc, lp):
            y, st = mamba_block(lp["mamba"], apply_norm(lp["ln"], xc, cfg),
                                cfg, ctx, return_state=True)
            return xc + y, st
        x, states = jax.lax.scan(body, x, params["layers"])
        cache = {"conv": states[0], "ssm": states[1]}

    elif plan == "zamba":
        every = cfg.hybrid.attn_every
        groups = cfg.num_layers // every
        gl = jax.tree.map(
            lambda a: a.reshape(groups, every, *a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def group_body(xc, group_params):
            def inner(xg, lp):
                y, st = mamba_block(
                    lp["mamba"], apply_norm(lp["ln"], xg, cfg), cfg, ctx,
                    return_state=True)
                return xg + y, st
            xg, states = jax.lax.scan(inner, xc, group_params)
            xg, kv = _apply_shared_attn_prefill(shared, xg, cfg, ctx,
                                                positions)
            return xg, (states, kv)
        x, (states, kvs) = jax.lax.scan(group_body, x, gl)
        cache = {"conv": states[0].reshape(cfg.num_layers,
                                           *states[0].shape[2:]),
                 "ssm": states[1].reshape(cfg.num_layers,
                                          *states[1].shape[2:]),
                 "attn_k": pad_cache(kvs[0]), "attn_v": pad_cache(kvs[1])}

    elif plan == "xlstm":
        def pair_body(xc, lp):
            mp, sp = lp
            ym, mst = xl.mlstm_block(
                mp["blk"], apply_norm(mp["ln"], xc, cfg), cfg, ctx,
                return_state=True)
            xc = xc + ym
            ys, sst = xl.slstm_block(
                sp["blk"], apply_norm(sp["ln"], xc, cfg), cfg, ctx,
                return_state=True)
            return xc + ys, (mst, sst)
        x, (mst, sst) = jax.lax.scan(
            pair_body, x, (params["mlstm_layers"], params["slstm_layers"]))
        cache = {"mlstm": mst, "slstm": sst}

    return apply_norm(params["final_norm"], x, cfg), cache


# --------------------------------------------------------------------------
# decode: one token, cache update
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Zero-initialized cache matching prefill()'s output structure."""
    plan = stack_plan(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    L = cfg.num_layers
    if plan == "uniform":
        if cfg.mla.enabled:
            return init_mla_cache(cfg, L, batch, max_len)
        return init_gqa_cache(cfg, L, batch, max_len)
    if plan == "mamba":
        d_inner, nheads, conv_ch, _ = mamba_dims(cfg)
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm.conv_kernel - 1, conv_ch),
                              cdt),
            "ssm": jnp.zeros((L, batch, nheads, cfg.ssm.head_dim,
                              cfg.ssm.state_dim), cdt),
        }
    if plan == "zamba":
        d_inner, nheads, conv_ch, _ = mamba_dims(cfg)
        groups = cfg.num_layers // cfg.hybrid.attn_every
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm.conv_kernel - 1, conv_ch),
                              cdt),
            "ssm": jnp.zeros((L, batch, nheads, cfg.ssm.head_dim,
                              cfg.ssm.state_dim), cdt),
            "attn_k": jnp.zeros((groups, batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), cdt),
            "attn_v": jnp.zeros((groups, batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), cdt),
        }
    if plan == "xlstm":
        n_pairs = cfg.num_layers // 2
        d_inner, h, dk = xl.mlstm_dims(cfg)
        k = cfg.xlstm.conv_kernel
        d = cfg.d_model
        return {
            "mlstm": (jnp.zeros((n_pairs, batch, k - 1, d_inner), cdt),
                      (jnp.zeros((n_pairs, batch, h, dk, dk), jnp.float32),
                       jnp.zeros((n_pairs, batch, h, dk), jnp.float32),
                       jnp.full((n_pairs, batch, h), -1e30, jnp.float32))),
            "slstm": (jnp.zeros((n_pairs, batch, k - 1, d), cdt),
                      (jnp.zeros((n_pairs, batch, d), jnp.float32),
                       jnp.zeros((n_pairs, batch, d), jnp.float32),
                       jnp.full((n_pairs, batch, d), -1e30, jnp.float32),
                       jnp.zeros((n_pairs, batch, d), jnp.float32))),
        }
    raise ValueError(plan)


def decode_step(params, embeds: jnp.ndarray, cfg: ModelConfig,
                ctx: ParallelCtx, cache: Any, pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Any]:
    """embeds (B, 1, d) new-token embeddings -> (hidden (B, 1, d), cache)."""
    plan = stack_plan(cfg)
    x = embeds

    if plan == "uniform":
        def body(xc, inp):
            lp, cache_l = inp
            x2, new_cache = apply_uniform_layer_decode(lp, xc, cfg, ctx,
                                                       cache_l, pos)
            return x2, new_cache
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    elif plan == "mamba":
        def body(xc, inp):
            lp, conv, ssm = inp
            y, (conv2, ssm2) = mamba_decode_step(
                lp["mamba"], apply_norm(lp["ln"], xc, cfg), cfg, ctx,
                (conv, ssm))
            return xc + y, (conv2, ssm2)
        x, (conv2, ssm2) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        new_cache = {"conv": conv2, "ssm": ssm2}

    elif plan == "zamba":
        every = cfg.hybrid.attn_every
        groups = cfg.num_layers // every
        gl = jax.tree.map(
            lambda a: a.reshape(groups, every, *a.shape[1:]),
            params["layers"])
        gconv = cache["conv"].reshape(groups, every, *cache["conv"].shape[1:])
        gssm = cache["ssm"].reshape(groups, every, *cache["ssm"].shape[1:])
        shared = params["shared_attn"]

        def group_body(xc, inp):
            gp, conv_g, ssm_g, k_g, v_g = inp

            def inner(xg, lp_state):
                lp, conv, ssm = lp_state
                y, st = mamba_decode_step(
                    lp["mamba"], apply_norm(lp["ln"], xg, cfg), cfg, ctx,
                    (conv, ssm))
                return xg + y, st
            xg, (conv2, ssm2) = jax.lax.scan(inner, xc,
                                             (gp, conv_g, ssm_g))
            xg, (k2, v2) = _apply_shared_attn_decode(shared, xg, cfg, ctx,
                                                     k_g, v_g, pos)
            return xg, (conv2, ssm2, k2, v2)
        x, (conv2, ssm2, k2, v2) = jax.lax.scan(
            group_body, x, (gl, gconv, gssm, cache["attn_k"],
                            cache["attn_v"]))
        new_cache = {
            "conv": conv2.reshape(cfg.num_layers, *conv2.shape[2:]),
            "ssm": ssm2.reshape(cfg.num_layers, *ssm2.shape[2:]),
            "attn_k": k2, "attn_v": v2,
        }

    elif plan == "xlstm":
        def pair_body(xc, inp):
            mp, sp, mst_conv, mst_cell, sst_conv, sst_cell = inp
            ym, mst2 = xl.mlstm_block_decode(
                mp["blk"], apply_norm(mp["ln"], xc, cfg), cfg, ctx,
                (mst_conv, mst_cell))
            xc = xc + ym
            ys, sst2 = xl.slstm_block_decode(
                sp["blk"], apply_norm(sp["ln"], xc, cfg), cfg, ctx,
                (sst_conv, sst_cell))
            return xc + ys, (mst2, sst2)
        mconv, mcell = cache["mlstm"]
        sconv, scell = cache["slstm"]
        x, (mst2, sst2) = jax.lax.scan(
            pair_body, x, (params["mlstm_layers"], params["slstm_layers"],
                           mconv, mcell, sconv, scell))
        new_cache = {"mlstm": (mst2[0], mst2[1]),
                     "slstm": (sst2[0], sst2[1])}

    return apply_norm(params["final_norm"], x, cfg), new_cache


# --------------------------------------------------------------------------
# paged decode: per-sequence depths over a shared block pool
# --------------------------------------------------------------------------


def init_paged_cache(cfg: ModelConfig, layout: PagedLayout) -> Any:
    """Zero-initialized paged block pool (uniform attention stacks only:
    recurrent plans keep O(1) state per sequence — nothing to page)."""
    plan = stack_plan(cfg)
    if plan != "uniform":
        raise ValueError(
            f"paged KV cache supports the uniform attention stack only, "
            f"got stack plan {plan!r}")
    if cfg.mla.enabled:
        return init_mla_paged_cache(cfg, cfg.num_layers, layout)
    return init_gqa_paged_cache(cfg, cfg.num_layers, layout)


def decode_step_paged(params, embeds: jnp.ndarray, cfg: ModelConfig,
                      ctx: ParallelCtx, cache: Any,
                      block_tables: jnp.ndarray, kv_lens: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, Any]:
    """One token per sequence against the paged pool.

    embeds (B, 1, d); block_tables (B, MB) int32; kv_lens (B,) int32 —
    each sequence attends to its own kv_lens[i] cached tokens plus the
    new one. Returns (hidden (B, 1, d), cache).
    """
    if stack_plan(cfg) != "uniform":
        raise ValueError("decode_step_paged requires the uniform stack")

    def body(xc, inp):
        lp, cache_l = inp
        x2, new_cache = apply_uniform_layer_decode_paged(
            lp, xc, cfg, ctx, cache_l, block_tables, kv_lens)
        return x2, new_cache
    x, new_cache = jax.lax.scan(body, embeds, (params["layers"], cache))
    return apply_norm(params["final_norm"], x, cfg), new_cache
