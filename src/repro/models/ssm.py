"""Mamba2 (SSD) block — used by zamba2 (hybrid) and available standalone.

Structure follows the Mamba2 reference: fused in_proj producing
[z, x, B, C, dt], causal depthwise conv over [x, B, C], softplus dt with
bias, SSD chunked scan (kernels/ssd_scan), gated RMSNorm, out_proj.

State for decode: (conv_state (B, K-1, conv_ch), ssm_state (B, H, P, N)).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models.blocks import ParallelCtx, _cast, batch_spec, constrain, dense_init


def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.ngroups * s.state_dim
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.state_dim + nheads
    return d_inner, nheads, conv_ch, d_in_proj


def init_mamba(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    s = cfg.ssm
    d_inner, nheads, conv_ch, d_in_proj = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[2], (nheads,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))    # inv_softplus
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, d_in_proj), dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_kernel, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)
                         ).astype(dt),
        "D": jnp.ones((nheads,), dt),
        "dt_bias": dt_bias.astype(dt),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), dt,
                               fan_in=d_inner),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x (B, S, C); w (K, C). Returns (y, tail).

    ``init`` is the (B, K-1, C) left-context from a previous segment (decode
    prefill chaining); tail is the new left-context after this segment.
    """
    bsz, s, c = x.shape
    k = w.shape[0]
    if init is None:
        init = jnp.zeros((bsz, k - 1, c), x.dtype)
    xp = jnp.concatenate([init, x], axis=1)            # (B, S+K-1, C)
    tail = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((bsz, 0, c), x.dtype)
    y = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    y = y + b.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype), tail


def _split_zxbcdt(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, conv_ch, _ = mamba_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt


def _split_xbc(xBC: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, _, _, _ = mamba_dims(cfg)
    gn = s.ngroups * s.state_dim
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + gn]
    Cm = xBC[..., d_inner + gn:]
    return x, Bm, Cm


def mamba_block(params, x: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
                initial_state: Optional[Tuple] = None,
                return_state: bool = False):
    """x (B, S, d_model) -> y (B, S, d_model) [, (conv_state, ssm_state)]."""
    s = cfg.ssm
    bsz, seq, _ = x.shape
    d_inner, nheads, conv_ch, _ = mamba_dims(cfg)
    cdt = cfg.compute_dtype

    zxbcdt = x @ _cast(params["in_proj"], cdt)
    zxbcdt = constrain(zxbcdt, ctx, batch_spec(ctx, None, ctx.tp_axis))
    z, xBC, dt = _split_zxbcdt(zxbcdt, cfg)
    conv_init = initial_state[0] if initial_state is not None else None
    xBC, conv_tail = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                  conv_init)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = _split_xbc(xBC, cfg)
    xs = xs.reshape(bsz, seq, nheads, s.head_dim)
    Bm = Bm.reshape(bsz, seq, s.ngroups, s.state_dim)
    Cm = Cm.reshape(bsz, seq, s.ngroups, s.state_dim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                          params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    ssm_init = initial_state[1] if initial_state is not None else None
    y, final = ssd_ops.ssd_scan(
        xs, dtv, A, Bm, Cm, params["D"].astype(jnp.float32),
        chunk_size=s.chunk_size, initial_state=ssm_init,
        impl="reference")
    y = y.reshape(bsz, seq, d_inner)
    y = constrain(y, ctx, batch_spec(ctx, None, ctx.tp_axis))
    from repro.models.blocks import rms_norm_gated
    y = rms_norm_gated(y, z, params["norm"])
    out = y @ _cast(params["out_proj"], cdt)
    out = constrain(out, ctx, batch_spec(ctx, None, None))
    if return_state:
        return out, (conv_tail, final.astype(cdt))
    return out


def mamba_decode_step(params, x: jnp.ndarray, cfg: ModelConfig,
                      ctx: ParallelCtx, state: Tuple):
    """One-token decode. x (B, 1, d); state (conv (B,K-1,C), ssm (B,H,P,N))."""
    s = cfg.ssm
    bsz = x.shape[0]
    d_inner, nheads, conv_ch, _ = mamba_dims(cfg)
    cdt = cfg.compute_dtype
    conv_state, ssm_state = state

    zxbcdt = (x[:, 0, :] @ _cast(params["in_proj"], cdt))   # (B, dproj)
    z, xBC, dt = _split_zxbcdt(zxbcdt, cfg)
    # conv over the (K-1) carried inputs + current
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B,K,C)
    new_conv = window[:, 1:, :]
    w = params["conv_w"].astype(jnp.float32)                 # (K, C)
    xBC = jnp.sum(window.astype(jnp.float32) * w[None], axis=1) + \
        params["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(xBC).astype(cdt)
    xs, Bm, Cm = _split_xbc(xBC, cfg)
    xs = xs.reshape(bsz, nheads, s.head_dim)
    Bm = Bm.reshape(bsz, s.ngroups, s.state_dim)
    Cm = Cm.reshape(bsz, s.ngroups, s.state_dim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                          params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, ssm_new = ssd_ops.ssd_decode_step(
        ssm_state.astype(jnp.float32), xs, dtv, A, Bm, Cm,
        params["D"].astype(jnp.float32))
    y = y.reshape(bsz, d_inner)
    from repro.models.blocks import rms_norm_gated
    y = rms_norm_gated(y, z, params["norm"])
    out = (y @ _cast(params["out_proj"], cdt))[:, None, :]
    return out, (new_conv, ssm_new.astype(cdt))
