"""Transformer building blocks: norms, RoPE, GQA/MLA attention, MLP, MoE.

All blocks are pure functions ``apply(params, x, ...)`` over plain dict
pytrees; ``init_*`` builds matching params. Params are stored in
``cfg.param_dtype`` and cast to ``cfg.compute_dtype`` at use. Distribution
is expressed outside (launch/sharding.py) except where the block itself is
a distributed algorithm (MoE expert parallelism, split-K decode) — those
take a :class:`ParallelCtx`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as attn_ops


# --------------------------------------------------------------------------
# Parallel context
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """How a model invocation is distributed.

    ``mesh=None`` means single-device (smoke tests); blocks then use their
    local math paths. ``dp_axes`` spans (pod, data); ``tp_axis`` is the
    model/tensor axis used for TP, EP and split-K sequence sharding.
    """

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None

    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for ax in self.dp_axes:
            n *= self.mesh.shape[ax]
        return n


LOCAL_CTX = ParallelCtx()


def _cast(x: jnp.ndarray, dtype_str: str) -> jnp.ndarray:
    return x.astype(jnp.dtype(dtype_str))


def constrain(x: jnp.ndarray, ctx: ParallelCtx, spec: P) -> jnp.ndarray:
    """with_sharding_constraint if distributed, else identity.

    Uses the bare-PartitionSpec form (ambient mesh): inside a partially-
    manual shard_map region (the hierarchical pod reduction) a
    NamedSharding over the full mesh would mix Manual and Auto axes.
    """
    if not ctx.distributed:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def batch_spec(ctx: ParallelCtx, *rest) -> P:
    """PartitionSpec with batch dim over DP axes followed by ``rest``."""
    return P(ctx.dp_axes if ctx.dp_axes else None, *rest)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dt)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dt),
                "bias": jnp.zeros((cfg.d_model,), dt)}
    if cfg.norm == "nonparam_ln":        # OLMo: no affine params
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
               cfg: ModelConfig, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        xf = xf * params["scale"].astype(jnp.float32)
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if params:
            xf = xf * params["scale"].astype(jnp.float32)
            if "bias" in params:
                xf = xf + params["bias"].astype(jnp.float32)
    return xf.astype(x.dtype)


def rms_norm_gated(x: jnp.ndarray, gate: jnp.ndarray,
                   scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Mamba2 gated RMSNorm: norm(x * silu(gate)) * scale."""
    xf = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (B, S, H, D) with positions (S,) or (B, S); rotate-half convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, :, None, :]                   # (1, S, 1, D/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]                      # (B, S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h * dh), dt),
        "wk": dense_init(k2, (d, hkv * dh), dt),
        "wv": dense_init(k3, (d, hkv * dh), dt),
        "wo": dense_init(k4, (h * dh, d), dt, fan_in=h * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _qk_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def attention_qkv(params, x: jnp.ndarray, cfg: ModelConfig,
                  positions: jnp.ndarray):
    """Project to rotated q, k and v. Returns (q, k, v) in (B,S,H,Dh)."""
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    q = (x @ _cast(params["wq"], cfg.compute_dtype)).reshape(b, s, h, dh)
    k = (x @ _cast(params["wk"], cfg.compute_dtype)).reshape(b, s, hkv, dh)
    v = (x @ _cast(params["wv"], cfg.compute_dtype)).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = _qk_norm(q, params["q_norm"])
        k = _qk_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q.astype(cdt), k.astype(cdt), v.astype(cdt)


def attention_block(params, x: jnp.ndarray, cfg: ModelConfig,
                    ctx: ParallelCtx, positions: jnp.ndarray,
                    q_offset: int = 0, return_kv: bool = False):
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = attention_qkv(params, x, cfg, positions)
    q = constrain(q, ctx, batch_spec(ctx, None, ctx.tp_axis, None))
    k = constrain(k, ctx, batch_spec(ctx, None,
                                     ctx.tp_axis if cfg.num_kv_heads >= ctx.tp_size else None,
                                     None))
    v = constrain(v, ctx, batch_spec(ctx, None,
                                     ctx.tp_axis if cfg.num_kv_heads >= ctx.tp_size else None,
                                     None))
    out = attn_ops.flash_attention(
        q, k, v, causal=True, q_offset=q_offset,
        impl=cfg.attention_impl if s > 1 else "dense",
        interpret=(s > 1 and cfg.attention_impl == "pallas" and
                   compat.pallas_interpret_fallback(
                       "flash attention (attention_impl='pallas')")))
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    y = out @ _cast(params["wo"], cfg.compute_dtype)
    y = constrain(y, ctx, batch_spec(ctx, None, None))
    if return_kv:
        return y, (k, v)
    return y


# --------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# --------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 8)
    p: Dict[str, jnp.ndarray] = {
        "w_dkv": dense_init(ks[0], (d, m.kv_lora_rank), dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "w_kr": dense_init(ks[1], (d, m.rope_head_dim), dt),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, h * m.nope_head_dim), dt),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, h * m.v_head_dim), dt),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), dt),
    }
    if m.q_lora_rank > 0:
        p["w_dq"] = dense_init(ks[5], (d, m.q_lora_rank), dt)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dt)
        p["w_uq"] = dense_init(ks[6], (m.q_lora_rank, h * qd), dt)
    else:
        p["wq"] = dense_init(ks[5], (d, h * qd), dt)
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def mla_queries(params, x, cfg: ModelConfig, positions):
    """q split into (q_nope (B,S,H,dn), q_rope (B,S,H,dr))."""
    b, s, _ = x.shape
    m, h = cfg.mla, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    if m.q_lora_rank > 0:
        ql = _rms(x @ _cast(params["w_dq"], cfg.compute_dtype), params["q_norm"])
        q = (ql @ _cast(params["w_uq"], cfg.compute_dtype)).reshape(b, s, h, qd)
    else:
        q = (x @ _cast(params["wq"], cfg.compute_dtype)).reshape(b, s, h, qd)
    q_nope = q[..., :m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latent(params, x, cfg: ModelConfig, positions):
    """Compressed KV latent: (c_kv (B,S,r), k_rope (B,S,dr))."""
    c_kv = _rms(x @ _cast(params["w_dkv"], cfg.compute_dtype), params["kv_norm"])
    k_r = x @ _cast(params["w_kr"], cfg.compute_dtype)
    k_r = apply_rope(k_r[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_r


def mla_block(params, x, cfg: ModelConfig, ctx: ParallelCtx,
              positions, q_offset: int = 0, return_kv: bool = False):
    """Train/prefill MLA: decompress per-head k/v, run flash attention."""
    b, s, _ = x.shape
    m, h = cfg.mla, cfg.num_heads
    q_nope, q_rope = mla_queries(params, x, cfg, positions)
    c_kv, k_r = mla_latent(params, x, cfg, positions)
    k_nope = (c_kv @ _cast(params["w_uk"], cfg.compute_dtype)
              ).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ _cast(params["w_uv"], cfg.compute_dtype)
         ).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, m.rope_head_dim))],
        axis=-1)
    q = constrain(q, ctx, batch_spec(ctx, None, ctx.tp_axis, None))
    k = constrain(k, ctx, batch_spec(ctx, None, ctx.tp_axis, None))
    v = constrain(v, ctx, batch_spec(ctx, None, ctx.tp_axis, None))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    # pad v to qk head dim so the kernel sees uniform D, then slice back
    dqk = m.nope_head_dim + m.rope_head_dim
    if m.v_head_dim < dqk:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - m.v_head_dim)))
    out = attn_ops.flash_attention(
        q, k, v, causal=True, q_offset=q_offset, softmax_scale=scale,
        impl=cfg.attention_impl if s > 1 else "dense",
        interpret=(s > 1 and cfg.attention_impl == "pallas" and
                   compat.pallas_interpret_fallback(
                       "MLA flash attention (attention_impl='pallas')")))
    out = out[..., :m.v_head_dim].reshape(b, s, h * m.v_head_dim)
    y = out @ _cast(params["wo"], cfg.compute_dtype)
    y = constrain(y, ctx, batch_spec(ctx, None, None))
    if return_kv:
        # cache the *compressed* latent (the MLA decode-path optimization)
        return y, (c_kv, k_r)
    return y


# --------------------------------------------------------------------------
# Dense MLP
# --------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, (d, ff), dt),
                "w_up": dense_init(k2, (d, ff), dt),
                "w_down": dense_init(k3, (ff, d), dt, fan_in=ff)}
    return {"w_up": dense_init(k1, (d, ff), dt),
            "w_down": dense_init(k2, (ff, d), dt, fan_in=ff)}


def mlp_block(params, x: jnp.ndarray, cfg: ModelConfig,
              ctx: ParallelCtx) -> jnp.ndarray:
    cdt = cfg.compute_dtype
    if "w_gate" in params:
        g = x @ _cast(params["w_gate"], cdt)
        u = x @ _cast(params["w_up"], cdt)
        g = constrain(g, ctx, batch_spec(ctx, None, ctx.tp_axis))
        u = constrain(u, ctx, batch_spec(ctx, None, ctx.tp_axis))
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(x @ _cast(params["w_up"], cdt))
        h = constrain(h, ctx, batch_spec(ctx, None, ctx.tp_axis))
    y = h @ _cast(params["w_down"], cdt)
    return constrain(y, ctx, batch_spec(ctx, None, None))


# --------------------------------------------------------------------------
# Mixture of Experts (GShard-style top-k, sort-free capacity dispatch)
# --------------------------------------------------------------------------
#
# Expert parallelism exploits that activations are replicated over the TP
# ("model") axis between blocks: each model-rank owns E/tp experts, selects
# the tokens routed to *its* experts locally (no all-to-all), runs its
# expert FFNs, scatters back, and a single psum over the model axis merges
# expert contributions — the same collective Megatron pays for a dense FFN.


def init_moe(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: Dict[str, jnp.ndarray] = {
        "router": dense_init(ks[0], (d, mo.num_experts), dt),
        "w_gate": dense_init(ks[1], (mo.num_experts, d, mo.expert_d_ff), dt,
                             fan_in=d),
        "w_up": dense_init(ks[2], (mo.num_experts, d, mo.expert_d_ff), dt,
                           fan_in=d),
        "w_down": dense_init(ks[3], (mo.num_experts, mo.expert_d_ff, d), dt,
                             fan_in=mo.expert_d_ff),
    }
    if mo.num_shared_experts > 0:
        ff = mo.shared_d_ff * mo.num_shared_experts
        p["shared"] = init_mlp(cfg, ks[4], d_ff=ff)
    return p


def _moe_compute_local(x2d: jnp.ndarray, gates: jnp.ndarray,
                       eidx: jnp.ndarray, w_gate, w_up, w_down,
                       e_start: int, e_local: int, capacity: int,
                       cfg: ModelConfig) -> jnp.ndarray:
    """Dispatch tokens to experts [e_start, e_start+e_local), compute, combine.

    x2d (T, d); gates/eidx (T, k). Returns this expert-range's contribution
    (T, d) — caller sums contributions across ranges (psum over EP axis).
    """
    t, d = x2d.shape
    k = eidx.shape[1]
    flat_e = eidx.reshape(-1)                         # (T*k,) token-major
    local_e = flat_e - e_start
    valid = (local_e >= 0) & (local_e < e_local)
    local_e_c = jnp.where(valid, local_e, 0)
    # position of each (token, expert) slot within its expert queue
    onehot = jax.nn.one_hot(local_e_c, e_local, dtype=jnp.int32) * valid[:, None]
    pos = jnp.cumsum(onehot, axis=0) - onehot          # exclusive prefix count
    pos_in_e = jnp.take_along_axis(pos, local_e_c[:, None], axis=1)[:, 0]
    keep = valid & (pos_in_e < capacity)
    slot_e = jnp.where(keep, local_e_c, e_local).reshape(t, k)   # OOB -> drop
    slot_c = jnp.where(keep, pos_in_e, capacity).reshape(t, k)
    # gather tokens into (E_local, C, d) buffers; loop over the k routing
    # slots so we never materialize a (T*k, d) gather
    buf = jnp.zeros((e_local, capacity, d), x2d.dtype)
    for j in range(k):
        buf = buf.at[slot_e[:, j], slot_c[:, j]].add(x2d, mode="drop")
    # expert FFN (batched over local experts)
    cdt = cfg.compute_dtype
    g = jnp.einsum("ecd,edf->ecf", buf, _cast(w_gate, cdt))
    u = jnp.einsum("ecd,edf->ecf", buf, _cast(w_up, cdt))
    act = jax.nn.silu(g) if cfg.activation in ("swiglu", "silu") else jax.nn.gelu(g)
    eo = jnp.einsum("ecf,efd->ecd", act * u, _cast(w_down, cdt))
    # combine back, weighted by router gates
    y = jnp.zeros((t, d), eo.dtype)
    for j in range(k):
        gj = gates[:, j].astype(eo.dtype)
        y = y + eo.at[slot_e[:, j], slot_c[:, j]].get(
            mode="fill", fill_value=0.0) * gj[:, None]
    return y


def _router(params, x2d: jnp.ndarray, cfg: ModelConfig):
    """Top-k routing. Returns (gates (T,k) f32, eidx (T,k) i32, aux_loss)."""
    mo = cfg.moe
    # native-dtype GEMM with f32 accumulation — a plain astype(f32) of
    # x2d materializes a (T, d) fp32 copy (XLA hoists it out of loops)
    logits = jax.lax.dot_general(
        x2d, _cast(params["router"], x2d.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, mo.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # GShard load-balancing aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                        # mean router prob
    ce = jnp.mean(
        jax.nn.one_hot(eidx[:, 0], mo.num_experts, dtype=jnp.float32), axis=0)
    aux = mo.num_experts * jnp.sum(me * ce) * mo.aux_loss_coef
    return gates, eidx, aux


def moe_block(params, x: jnp.ndarray, cfg: ModelConfig,
              ctx: ParallelCtx, train: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss). x (B, S, d).

    ``train=False`` (prefill/decode) uses the generous eval capacity —
    and for single-token decode the exact no-drop capacity — since
    capacity dropping is a training-time regularizer, not serving
    behaviour.
    """
    mo = cfg.moe
    b, s, d = x.shape

    def capacity_for(tokens: int, experts: int) -> int:
        if not train and s == 1:
            return max(8, -(-tokens * mo.top_k // 8) * 8)   # no-drop decode
        cf = mo.capacity_factor if train else mo.capacity_factor_eval
        cap = int(math.ceil(tokens * mo.top_k * cf / experts))
        return max(8, -(-cap // 8) * 8)                # pad to multiple of 8

    if not ctx.distributed or ctx.tp_axis is None:
        x2d = x.reshape(b * s, d)
        gates, eidx, aux = _router(params, x2d, cfg)
        y = _moe_compute_local(
            x2d, gates.astype(x.dtype), eidx,
            params["w_gate"], params["w_up"], params["w_down"],
            0, mo.num_experts, capacity_for(b * s, mo.num_experts), cfg)
        out = y.reshape(b, s, d)
    else:
        tp = ctx.tp_size
        e_local = mo.num_experts // tp
        dp = ctx.dp_size
        t_local = (b // dp) * s if b >= dp else s
        cap = capacity_for(t_local, mo.num_experts)
        mesh = ctx.mesh
        dp_axes = ctx.dp_axes

        def sharded_moe(x_loc, router_w, w_gate, w_up, w_down):
            bl, sl, dl = x_loc.shape
            x2d = x_loc.reshape(bl * sl, dl)
            gates, eidx, aux = _router({"router": router_w}, x2d, cfg)
            rank = jax.lax.axis_index(ctx.tp_axis)
            y = _moe_compute_local(
                x2d, gates.astype(x_loc.dtype), eidx,
                w_gate, w_up, w_down,
                rank * e_local, e_local, cap, cfg)
            y = jax.lax.psum(y, ctx.tp_axis)
            aux = aux / jax.lax.psum(1.0, dp_axes) if dp_axes else aux
            aux = jax.lax.psum(aux, dp_axes) if dp_axes else aux
            return y.reshape(bl, sl, dl), aux

        spec_x = P(dp_axes if dp_axes else None, None, None)
        # mesh=None -> ambient mesh: a concrete all-Auto mesh object
        # would clash with the partially-manual context inside the
        # hierarchical pod reduction (nested shard_map)
        out, aux = compat.shard_map(
            sharded_moe, mesh=None,
            in_specs=(spec_x, P(None, None),
                      P(ctx.tp_axis, None, None), P(ctx.tp_axis, None, None),
                      P(ctx.tp_axis, None, None)),
            out_specs=(spec_x, P()),
            check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])

    if mo.num_shared_experts > 0:
        out = out + mlp_block(params["shared"], x, cfg, ctx)
    return out, (aux if isinstance(aux, jnp.ndarray) else jnp.float32(aux))
