"""KV caches and single-token decode attention (GQA + absorbed MLA).

Cache layouts (per layer; stacked with a leading L dim by the stack):
  GQA : k/v (B, S_max, Hkv, Dh) in compute dtype
  MLA : c_kv (B, S_max, r) latent + k_rope (B, S_max, Dr) — the
        compressed-latent cache that makes DeepSeek-V2 decode cheap.

Decode attention is single-query attention over the cache with a
``kv_len`` mask; MLA uses the *absorbed* formulation: W_uk is folded into
the query and W_uv into the output so the latent is never decompressed —
scores are (B, H, S) against the shared latent, MQA-style.

Sharding at scale (launch/sharding.py): caches shard batch over the DP
axes; when per-device batch is small and the cache is large (deepseek
decode_32k), the sequence dim shards over "model" instead and the
softmax is computed with a cross-shard logsumexp fix-up (split-K) — see
launch/steps.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ref as attn_ref
from repro.models.blocks import (ParallelCtx, _cast, apply_rope,
                                 attention_qkv, batch_spec, constrain,
                                 mla_latent, mla_queries)


# --------------------------------------------------------------------------
# cache constructors
# --------------------------------------------------------------------------


def init_gqa_cache(cfg: ModelConfig, num_layers: int, batch: int,
                   max_len: int) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def init_mla_cache(cfg: ModelConfig, num_layers: int, batch: int,
                   max_len: int) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((num_layers, batch, max_len, m.kv_lora_rank), cdt),
        "k_rope": jnp.zeros((num_layers, batch, max_len, m.rope_head_dim),
                            cdt),
    }


# --------------------------------------------------------------------------
# GQA decode
# --------------------------------------------------------------------------


def attention_decode(params, x: jnp.ndarray, cfg: ModelConfig,
                     ctx: ParallelCtx, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray):
    """One-token attention. x (B, 1, d); caches (B, S_max, Hkv, Dh).

    ``pos`` is the scalar index of the new token (kv_len becomes pos+1).
    Returns (y (B, 1, d), (k_cache, v_cache) updated).
    """
    b = x.shape[0]
    positions = jnp.reshape(pos, (1,))
    q, k, v = attention_qkv(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    # dense single-query attention: with the cache sequence dim sharded
    # over "model" (split-K spec), XLA partitions the softmax reduction
    # across ranks automatically. A chunked python-level loop over the
    # sharded dim BREAKS that (each chunk broadcast to all ranks) —
    # measured +60% ICI — see EXPERIMENTS.md §Perf (refuted hypothesis).
    out = attn_ref.mha_dense(q, k_cache, v_cache, causal=False,
                             kv_len=kv_len)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    y = out @ _cast(params["wo"], cfg.compute_dtype)
    return constrain(y, ctx, batch_spec(ctx, None, None)), (k_cache, v_cache)


# --------------------------------------------------------------------------
# MLA decode (absorbed, latent-space attention)
# --------------------------------------------------------------------------


def mla_decode(params, x: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
               ckv_cache: jnp.ndarray, kr_cache: jnp.ndarray,
               pos: jnp.ndarray):
    """One-token MLA attention over the compressed-latent cache.

    x (B, 1, d); ckv_cache (B, S_max, r); kr_cache (B, S_max, Dr).

    Dense (non-chunked) on purpose: the latent cache's sequence dim is
    sharded over "model" (split-K, launch/sharding.py) and XLA
    partitions the softmax + weighted-sum reductions across ranks
    automatically. A host-level chunk loop over the sharded dim forces
    per-chunk broadcasts instead (+60% ICI measured) — refuted §Perf
    hypothesis; the one-HBM-pass variant belongs in a Pallas kernel.
    """
    b = x.shape[0]
    m, h = cfg.mla, cfg.num_heads
    cdt = cfg.compute_dtype
    positions = jnp.reshape(pos, (1,))
    q_nope, q_rope = mla_queries(params, x, cfg, positions)  # (B,1,H,*)
    c_kv, k_r = mla_latent(params, x, cfg, positions)        # (B,1,r),(B,1,Dr)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, k_r.astype(kr_cache.dtype), (0, pos, 0))

    # absorb W_uk into the query: q_abs[b,h,r] = q_nope . W_uk[.,h,.]
    w_uk = _cast(params["w_uk"], cdt).reshape(
        m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32).astype(cdt)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_cache,
                         preferred_element_type=jnp.float32) +
              jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(cdt),
                         kr_cache,
                         preferred_element_type=jnp.float32)) * scale
    s_max = ckv_cache.shape[1]
    mask = jnp.arange(s_max)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache,
                         preferred_element_type=jnp.float32)
    w_uv = _cast(params["w_uv"], cdt).reshape(
        m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(cdt), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(cdt)
    y = out @ _cast(params["wo"], cdt)
    return (constrain(y, ctx, batch_spec(ctx, None, None)),
            (ckv_cache, kr_cache))

