"""KV caches and single-token decode attention (GQA + absorbed MLA).

Two cache families live here:

**Contiguous** (static-batch serving, one slab per sequence slot;
stacked with a leading L dim by the stack):
  GQA : k/v (B, S_max, Hkv, Dh) in compute dtype
  MLA : c_kv (B, S_max, r) latent + k_rope (B, S_max, Dr) — the
        compressed-latent cache that makes DeepSeek-V2 decode cheap.

**Paged** (continuous-batching serving, ``repro.serve``): the cache is
a pool of fixed-size blocks — the inference twin of the flat bucket
stack in core/buckets.py — and each sequence owns a *block table*
mapping its logical block j to a physical pool slot:
  GQA : k/v (L, N, bs, Hkv, Dh)
  MLA : c_kv (L, N, bs, r) + k_rope (L, N, bs, Dr)
where N = pool blocks and bs = block size. Decode takes a per-sequence
``kv_lens`` vector instead of the scalar ``pos``: every sequence in the
batch sits at its own depth, so long and short requests share one
decode step without padding to the global max. Writes at out-of-pool
block ids (the NULL_BLOCK sentinel of retired/empty slots) are
dropped; gathers of unmapped blocks return zeros, exactly matching the
zero-initialized contiguous cache — which is what keeps the paged path
bit-identical to the static path in fp32.

Decode attention is single-query attention over the cache with a
``kv_len`` mask; MLA uses the *absorbed* formulation: W_uk is folded into
the query and W_uv into the output so the latent is never decompressed —
scores are (B, H, S) against the shared latent, MQA-style.

Sharding at scale (launch/sharding.py): caches shard batch over the DP
axes; when per-device batch is small and the cache is large (deepseek
decode_32k), the sequence dim shards over "model" instead and the
softmax is computed with a cross-shard logsumexp fix-up (split-K) — see
launch/steps.py. Paged pools shard KV heads / the latent rank over
"model" (``sharding.paged_cache_specs``); the block dim stays
replicated so block tables index identically on every rank.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.flash_attention import ref as attn_ref
from repro.kernels.mla_decode import ops as mla_ops
from repro.models.blocks import (ParallelCtx, _cast, apply_rope,
                                 attention_qkv, batch_spec, constrain,
                                 mla_latent, mla_queries)


# --------------------------------------------------------------------------
# cache constructors
# --------------------------------------------------------------------------


def init_gqa_cache(cfg: ModelConfig, num_layers: int, batch: int,
                   max_len: int) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def init_mla_cache(cfg: ModelConfig, num_layers: int, batch: int,
                   max_len: int) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((num_layers, batch, max_len, m.kv_lora_rank), cdt),
        "k_rope": jnp.zeros((num_layers, batch, max_len, m.rope_head_dim),
                            cdt),
    }


# --------------------------------------------------------------------------
# GQA decode
# --------------------------------------------------------------------------


def attention_decode(params, x: jnp.ndarray, cfg: ModelConfig,
                     ctx: ParallelCtx, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray):
    """One-token attention. x (B, 1, d); caches (B, S_max, Hkv, Dh).

    ``pos`` is the scalar index of the new token (kv_len becomes pos+1).
    Returns (y (B, 1, d), (k_cache, v_cache) updated).
    """
    b = x.shape[0]
    positions = jnp.reshape(pos, (1,))
    q, k, v = attention_qkv(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    # dense single-query attention: with the cache sequence dim sharded
    # over "model" (split-K spec), XLA partitions the softmax reduction
    # across ranks automatically. A chunked python-level loop over the
    # sharded dim BREAKS that (each chunk broadcast to all ranks) —
    # measured +60% ICI — see EXPERIMENTS.md §Perf (refuted hypothesis).
    out = attn_ref.mha_dense(q, k_cache, v_cache, causal=False,
                             kv_len=kv_len)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    y = out @ _cast(params["wo"], cfg.compute_dtype)
    return constrain(y, ctx, batch_spec(ctx, None, None)), (k_cache, v_cache)


# --------------------------------------------------------------------------
# MLA decode (absorbed, latent-space attention)
# --------------------------------------------------------------------------


def mla_decode(params, x: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
               ckv_cache: jnp.ndarray, kr_cache: jnp.ndarray,
               pos: jnp.ndarray):
    """One-token MLA attention over the compressed-latent cache.

    x (B, 1, d); ckv_cache (B, S_max, r); kr_cache (B, S_max, Dr).

    Dense (non-chunked) on purpose: the latent cache's sequence dim is
    sharded over "model" (split-K, launch/sharding.py) and XLA
    partitions the softmax + weighted-sum reductions across ranks
    automatically. A host-level chunk loop over the sharded dim forces
    per-chunk broadcasts instead (+60% ICI measured) — refuted §Perf
    hypothesis; the one-HBM-pass variant belongs in a Pallas kernel.
    """
    b = x.shape[0]
    m, h = cfg.mla, cfg.num_heads
    cdt = cfg.compute_dtype
    positions = jnp.reshape(pos, (1,))
    q_nope, q_rope = mla_queries(params, x, cfg, positions)  # (B,1,H,*)
    c_kv, k_r = mla_latent(params, x, cfg, positions)        # (B,1,r),(B,1,Dr)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, k_r.astype(kr_cache.dtype), (0, pos, 0))

    # absorb W_uk into the query: q_abs[b,h,r] = q_nope . W_uk[.,h,.]
    w_uk = _cast(params["w_uk"], cdt).reshape(
        m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32).astype(cdt)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_cache,
                         preferred_element_type=jnp.float32) +
              jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(cdt),
                         kr_cache,
                         preferred_element_type=jnp.float32)) * scale
    s_max = ckv_cache.shape[1]
    mask = jnp.arange(s_max)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache,
                         preferred_element_type=jnp.float32)
    w_uv = _cast(params["w_uv"], cdt).reshape(
        m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(cdt), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(cdt)
    y = out @ _cast(params["wo"], cdt)
    return (constrain(y, ctx, batch_spec(ctx, None, None)),
            (ckv_cache, kr_cache))


# --------------------------------------------------------------------------
# paged cache: layout, constructors, prefill scatter
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Geometry of a paged KV pool.

    The pool holds ``num_blocks`` physical blocks of ``block_size``
    tokens each; a sequence may map at most ``max_blocks_per_seq``
    logical blocks. Unmapped block-table entries hold ``null_block``
    (== num_blocks, one past the pool): scatters there are dropped and
    gathers there fill with zeros, so a NULL entry behaves exactly like
    untouched zero-initialized cache.
    """
    block_size: int
    num_blocks: int
    max_blocks_per_seq: int

    def __post_init__(self):
        if self.block_size <= 0 or self.num_blocks <= 0:
            raise ValueError(
                f"PagedLayout needs positive block_size/num_blocks, got "
                f"{self.block_size}/{self.num_blocks}")
        if self.max_blocks_per_seq <= 0:
            raise ValueError("PagedLayout.max_blocks_per_seq must be "
                             f"positive, got {self.max_blocks_per_seq}")

    @property
    def null_block(self) -> int:
        return self.num_blocks

    @property
    def max_seq_len(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold n_tokens (ceil-div; 0 tokens -> 0)."""
        return -(-n_tokens // self.block_size)


def init_gqa_paged_cache(cfg: ModelConfig, num_layers: int,
                         layout: PagedLayout) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (num_layers, layout.num_blocks, layout.block_size,
             cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def init_mla_paged_cache(cfg: ModelConfig, num_layers: int,
                         layout: PagedLayout) -> Dict[str, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    base = (num_layers, layout.num_blocks, layout.block_size)
    return {
        "c_kv": jnp.zeros(base + (m.kv_lora_rank,), cdt),
        "k_rope": jnp.zeros(base + (m.rope_head_dim,), cdt),
    }


def write_prefill_blocks(paged: Dict[str, jnp.ndarray],
                         contiguous: Dict[str, jnp.ndarray],
                         block_tables: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Scatter a contiguous prefill cache into the paged pool.

    ``contiguous`` leaves are (L, B, S_pad, ...) with S_pad a multiple
    of the block size; ``block_tables`` is (B, >= S_pad // bs). Row j
    of sequence i's chunked cache lands in physical block
    ``block_tables[i, j]``; NULL entries drop the write. Tokens past a
    sequence's real length carry padding-token K/V — they are masked
    out by the per-sequence ``kv_lens`` at decode and overwritten in
    place as decode advances, so they never reach an output.
    """
    def _scatter(dst, src):
        l, b, s_pad = src.shape[:3]
        bs = dst.shape[2]
        if s_pad % bs:
            raise ValueError(
                f"prefill length {s_pad} not a multiple of block size "
                f"{bs}")
        nc = s_pad // bs
        chunks = src.reshape((l, b, nc, bs) + src.shape[3:])
        return dst.at[:, block_tables[:, :nc]].set(
            chunks.astype(dst.dtype), mode="drop")

    return {name: _scatter(paged[name], contiguous[name])
            for name in paged}


# --------------------------------------------------------------------------
# paged GQA decode (per-sequence kv_lens + block tables)
# --------------------------------------------------------------------------


def attention_decode_paged(params, x: jnp.ndarray, cfg: ModelConfig,
                           ctx: ParallelCtx, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray,
                           block_tables: jnp.ndarray,
                           kv_lens: jnp.ndarray):
    """One-token attention over a paged pool, one depth per sequence.

    x (B, 1, d); caches (N, bs, Hkv, Dh); block_tables (B, MB) int32;
    kv_lens (B,) int32 — tokens already cached per sequence (the new
    token is written at position kv_lens[i] and attended to, so the
    effective context is kv_lens + 1). Sequences whose current block
    is NULL (inactive slots) write nowhere, gather zeros, and produce
    garbage the caller discards.
    Returns (y (B, 1, d), (k_cache, v_cache) updated).
    """
    b = x.shape[0]
    bs = k_cache.shape[1]
    positions = kv_lens[:, None]                        # (B, 1)
    q, k, v = attention_qkv(params, x, cfg, positions)
    blk = jnp.take_along_axis(
        block_tables, (kv_lens // bs)[:, None], axis=1)[:, 0]
    off = kv_lens % bs
    k_cache = k_cache.at[blk, off].set(
        k[:, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[blk, off].set(
        v[:, 0].astype(v_cache.dtype), mode="drop")
    # attention over the pool, per cfg.attention_impl: the reference
    # path gathers each sequence's mapped blocks back into a dense view
    # (NULL entries fill with zeros — bit-identical to untouched
    # contiguous cache, which keeps it bitwise equal to
    # attention_decode in fp32); the pallas path gathers blocks through
    # the block table INSIDE the kernel (no HBM window), fp32-bitwise
    # vs the reference, and runs interpreted with a loud warning where
    # the backend can't compile Pallas.
    out = attn_ops.flash_decode_paged(
        q, k_cache, v_cache, block_tables, kv_lens + 1,
        impl=cfg.attention_impl,
        interpret=(cfg.attention_impl == "pallas" and
                   compat.pallas_interpret_fallback(
                       "paged GQA decode (attention_impl='pallas')")))
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    y = out @ _cast(params["wo"], cfg.compute_dtype)
    return constrain(y, ctx, batch_spec(ctx, None, None)), (k_cache, v_cache)


# --------------------------------------------------------------------------
# paged MLA decode (absorbed, latent-space attention)
# --------------------------------------------------------------------------


def mla_decode_paged(params, x: jnp.ndarray, cfg: ModelConfig,
                     ctx: ParallelCtx, ckv_cache: jnp.ndarray,
                     kr_cache: jnp.ndarray, block_tables: jnp.ndarray,
                     kv_lens: jnp.ndarray):
    """Paged twin of :func:`mla_decode`.

    x (B, 1, d); ckv_cache (N, bs, r); kr_cache (N, bs, Dr);
    block_tables (B, MB); kv_lens (B,). Same absorbed formulation —
    scores against the gathered latent view, mask positions >= kv_len+1.

    ``cfg.attention_impl="pallas"`` replaces the materialized gather
    with the in-kernel block-table stream
    (kernels/mla_decode/mla_decode.py, one HBM pass over the latent
    pool), within compute-dtype tolerance of this reference; on
    backends that can't compile Pallas it runs interpreted with a loud
    warning (compat.pallas_interpret_fallback).
    """
    b = x.shape[0]
    m, h = cfg.mla, cfg.num_heads
    cdt = cfg.compute_dtype
    bs = ckv_cache.shape[1]
    positions = kv_lens[:, None]                        # (B, 1)
    q_nope, q_rope = mla_queries(params, x, cfg, positions)  # (B,1,H,*)
    c_kv, k_r = mla_latent(params, x, cfg, positions)   # (B,1,r),(B,1,Dr)
    blk = jnp.take_along_axis(
        block_tables, (kv_lens // bs)[:, None], axis=1)[:, 0]
    off = kv_lens % bs
    ckv_cache = ckv_cache.at[blk, off].set(
        c_kv[:, 0].astype(ckv_cache.dtype), mode="drop")
    kr_cache = kr_cache.at[blk, off].set(
        k_r[:, 0].astype(kr_cache.dtype), mode="drop")
    w_uk = _cast(params["w_uk"], cdt).reshape(
        m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32).astype(cdt)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    if cfg.attention_impl == "pallas":
        out_lat = mla_ops.mla_decode_paged_attention(
            q_abs, q_rope[:, 0].astype(cdt), ckv_cache, kr_cache,
            block_tables, kv_lens + 1, scale, impl="pallas",
            interpret=compat.pallas_interpret_fallback(
                "paged MLA decode (attention_impl='pallas')"))
    else:
        ckv_g = ckv_cache.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, m.kv_lora_rank)
        kr_g = kr_cache.at[block_tables].get(
            mode="fill", fill_value=0).reshape(b, -1, m.rope_head_dim)
        scores = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_g,
                             preferred_element_type=jnp.float32) +
                  jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(cdt),
                             kr_g,
                             preferred_element_type=jnp.float32)) * scale
        s_g = ckv_g.shape[1]
        mask = jnp.arange(s_g)[None, None, :] < \
            (kv_lens + 1)[:, None, None]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_g,
                             preferred_element_type=jnp.float32)
    w_uv = _cast(params["w_uv"], cdt).reshape(
        m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(cdt), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(cdt)
    y = out @ _cast(params["wo"], cdt)
    return (constrain(y, ctx, batch_spec(ctx, None, None)),
            (ckv_cache, kr_cache))

