"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

The xlstm-125m architecture alternates mLSTM blocks (parallelizable via
the chunkwise scan in kernels/mlstm_scan) with sLSTM blocks (sequential
recurrence with block-diagonal per-head recurrent weights; inherently
serial — we scan over time). d_ff=0 in the assigned config means there is
no separate FFN sub-block: the mLSTM block carries an internal 2x
up-projection and the sLSTM block a gated (4/3x) post-FFN, as in the
paper.

Decode state:
  mLSTM: (C (B,H,dk,dv), n (B,H,dk), m (B,H)) + conv tail (B,K-1,d_inner)
  sLSTM: (c, n, m, h) each (B, d_model) + conv tail (B,K-1,d_model)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.mlstm_scan import ops as mlstm_ops
from repro.models.blocks import ParallelCtx, _cast, dense_init
from repro.models.ssm import _causal_conv


# --------------------------------------------------------------------------
# mLSTM block
# --------------------------------------------------------------------------


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    h = cfg.xlstm.num_heads
    dk = d_inner // h
    return d_inner, h, dk


def init_mlstm_block(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_inner, h, dk = mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, d_inner), dt),
        "w_u": dense_init(ks[1], (d, d_inner), dt),
        "conv_w": (jax.random.normal(ks[2], (k, d_inner), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "w_q": dense_init(ks[3], (d_inner, d_inner), dt),
        "w_k": dense_init(ks[4], (d_inner, d_inner), dt),
        "w_v": dense_init(ks[5], (d_inner, d_inner), dt),
        "w_if": dense_init(ks[6], (d_inner, 2 * h), dt),
        "b_if": jnp.concatenate([jnp.zeros((h,), jnp.float32),
                                 jnp.linspace(3.0, 6.0, h)]).astype(dt),
        "skip": jnp.ones((d_inner,), dt),
        "out_norm": jnp.ones((d_inner,), dt),
        "w_down": dense_init(ks[7], (d_inner, d), dt, fan_in=d_inner),
    }


def _headwise_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
                      eps: float = 1e-5) -> jnp.ndarray:
    """x (B, S, H, dv); scale (H*dv,). Per-head normalization."""
    b, s, h, dv = x.shape
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf.reshape(b, s, h * dv) *
            scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_block(params, x: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
                initial_state=None, return_state: bool = False):
    """x (B, S, d) -> y (B, S, d) [, state]. Residual added by caller."""
    b, s, _ = x.shape
    d_inner, h, dk = mlstm_dims(cfg)
    cdt = cfg.compute_dtype

    z = x @ _cast(params["w_z"], cdt)
    u = x @ _cast(params["w_u"], cdt)
    conv_init = initial_state[0] if initial_state is not None else None
    c, conv_tail = _causal_conv(u, params["conv_w"], params["conv_b"],
                                conv_init)
    c = jax.nn.silu(c)
    q = (c @ _cast(params["w_q"], cdt)).reshape(b, s, h, dk)
    k = (c @ _cast(params["w_k"], cdt)).reshape(b, s, h, dk)
    v = (u @ _cast(params["w_v"], cdt)).reshape(b, s, h, dk)
    gates = c @ _cast(params["w_if"], cdt) + \
        params["b_if"].astype(cdt)[None, None, :]
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    ssm_init = initial_state[1] if initial_state is not None else None
    hseq, final = mlstm_ops.mlstm_scan(
        q, k, v, i_pre.astype(jnp.float32), f_pre.astype(jnp.float32),
        initial_state=ssm_init, impl="reference")
    hn = _headwise_rmsnorm(hseq, params["out_norm"])
    hn = hn + params["skip"].astype(cdt)[None, None, :] * c
    hn = hn * jax.nn.silu(z)
    out = hn @ _cast(params["w_down"], cdt)
    if return_state:
        return out, (conv_tail, final)
    return out


def mlstm_block_decode(params, x: jnp.ndarray, cfg: ModelConfig,
                       ctx: ParallelCtx, state):
    """One-token decode. x (B, 1, d); state (conv_tail, (C, n, m))."""
    b = x.shape[0]
    d_inner, h, dk = mlstm_dims(cfg)
    cdt = cfg.compute_dtype
    conv_state, (C, n, m) = state

    z = (x[:, 0] @ _cast(params["w_z"], cdt))
    u = (x[:, 0] @ _cast(params["w_u"], cdt))
    window = jnp.concatenate([conv_state, u[:, None, :]], axis=1)
    new_conv = window[:, 1:, :]
    w = params["conv_w"].astype(jnp.float32)
    c = jnp.sum(window.astype(jnp.float32) * w[None], axis=1) + \
        params["conv_b"].astype(jnp.float32)
    c = jax.nn.silu(c).astype(cdt)
    q = (c @ _cast(params["w_q"], cdt)).reshape(b, h, dk)
    k = (c @ _cast(params["w_k"], cdt)).reshape(b, h, dk)
    v = (u @ _cast(params["w_v"], cdt)).reshape(b, h, dk)
    gates = c @ _cast(params["w_if"], cdt) + params["b_if"].astype(cdt)[None]
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    hvec, new_state = mlstm_ops.mlstm_decode_step(
        (C, n, m), q, k, v, i_pre.astype(jnp.float32),
        f_pre.astype(jnp.float32))
    hvec = hvec[:, None, :, :]                     # (B, 1, H, dk)
    hn = _headwise_rmsnorm(hvec.astype(cdt), params["out_norm"])[:, 0]
    hn = hn + params["skip"].astype(cdt)[None, :] * c
    hn = hn * jax.nn.silu(z)
    out = (hn @ _cast(params["w_down"], cdt))[:, None, :]
    return out, (new_conv, new_state)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_inner, h, dk = mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    cdt = jnp.dtype(cfg.compute_dtype)
    return (jnp.zeros((batch, k - 1, d_inner), cdt),
            (jnp.zeros((batch, h, dk, dk), jnp.float32),
             jnp.zeros((batch, h, dk), jnp.float32),
             jnp.full((batch, h), -1e30, jnp.float32)))


# --------------------------------------------------------------------------
# sLSTM block
# --------------------------------------------------------------------------


def init_slstm_block(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    h = cfg.xlstm.num_heads
    dh = d // h
    k = cfg.xlstm.conv_kernel
    ff = int(cfg.xlstm.proj_factor_slstm * d)
    ks = jax.random.split(key, 7)
    return {
        "conv_w": (jax.random.normal(ks[0], (k, d), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((d,), dt),
        "w_ifzo": dense_init(ks[1], (d, 4 * d), dt),
        # block-diagonal per-head recurrent weights (H, dh, 4*dh)
        "r_ifzo": (jax.random.normal(ks[2], (h, dh, 4 * dh), jnp.float32)
                   / jnp.sqrt(dh)).astype(dt),
        "b_ifzo": jnp.concatenate(
            [jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d),
             jnp.zeros((2 * d,))]).astype(dt),
        "out_norm": jnp.ones((d,), dt),
        "ffn_gate": dense_init(ks[4], (d, ff), dt),
        "ffn_up": dense_init(ks[5], (d, ff), dt),
        "ffn_down": dense_init(ks[6], (ff, d), dt, fan_in=ff),
    }


def _slstm_cell(carry, gates_x, r_ifzo, h_heads):
    """One sLSTM time step. gates_x (B, 4d) pre-activations from input."""
    c, n, m, hprev = carry                          # each (B, d)
    b, d = c.shape
    nh, dh = r_ifzo.shape[0], r_ifzo.shape[1]
    # recurrent contribution, block-diagonal over heads
    hh = hprev.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hdf->bhf", hh, r_ifzo).reshape(b, 4 * d)
    g = gates_x + rec
    it, ft, zt, ot = jnp.split(g, 4, axis=-1)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_g = jnp.exp(it - m_new)
    f_g = jnp.exp(lf + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(zt)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def _slstm_scan(params, xconv: jnp.ndarray, x_raw: jnp.ndarray,
                cfg: ModelConfig, initial=None):
    """xconv/x_raw (B, S, d) -> h (B, S, d), final carry."""
    b, s, d = xconv.shape
    h = cfg.xlstm.num_heads
    # i,f gates see the conv path; z,o the raw path (xLSTM paper)
    gx = jnp.concatenate([
        xconv @ _cast(params["w_ifzo"], "float32")[:, :2 * d],
        x_raw @ _cast(params["w_ifzo"], "float32")[:, 2 * d:]], axis=-1)
    gx = gx.astype(jnp.float32) + params["b_ifzo"].astype(jnp.float32)
    r = params["r_ifzo"].astype(jnp.float32)
    if initial is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        initial = (zeros, zeros, jnp.full((b, d), -1e30, jnp.float32), zeros)

    def step(carry, g_t):
        return _slstm_cell(carry, g_t, r, h)

    final, hs = jax.lax.scan(step, initial, gx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), final


def slstm_block(params, x: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx,
                initial_state=None, return_state: bool = False):
    """x (B, S, d) -> y (B, S, d). Residual added by caller."""
    cdt = cfg.compute_dtype
    conv_init = initial_state[0] if initial_state is not None else None
    xc, conv_tail = _causal_conv(x, params["conv_w"], params["conv_b"],
                                 conv_init)
    xc = jax.nn.silu(xc)
    cell_init = initial_state[1] if initial_state is not None else None
    hs, final = _slstm_scan(params, xc.astype(jnp.float32),
                            x.astype(jnp.float32), cfg, cell_init)
    hf = hs.astype(jnp.float32)
    hf = hf * jax.lax.rsqrt(jnp.mean(hf * hf, axis=-1, keepdims=True) + 1e-5)
    hn = (hf * params["out_norm"].astype(jnp.float32)).astype(cdt)
    # gated FFN (proj factor 4/3)
    g = hn @ _cast(params["ffn_gate"], cdt)
    u = hn @ _cast(params["ffn_up"], cdt)
    out = (jax.nn.silu(g) * u) @ _cast(params["ffn_down"], cdt)
    if return_state:
        return out, (conv_tail, final)
    return out


def slstm_block_decode(params, x: jnp.ndarray, cfg: ModelConfig,
                       ctx: ParallelCtx, state):
    """One-token decode. state (conv_tail, (c, n, m, h))."""
    b = x.shape[0]
    d = cfg.d_model
    cdt = cfg.compute_dtype
    conv_state, cell = state
    window = jnp.concatenate([conv_state, x], axis=1)
    new_conv = window[:, 1:, :]
    w = params["conv_w"].astype(jnp.float32)
    xc = jnp.sum(window.astype(jnp.float32) * w[None], axis=1) + \
        params["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc)
    xr = x[:, 0].astype(jnp.float32)
    wz = params["w_ifzo"].astype(jnp.float32)
    gx = jnp.concatenate([xc @ wz[:, :2 * d], xr @ wz[:, 2 * d:]], axis=-1)
    gx = gx + params["b_ifzo"].astype(jnp.float32)
    new_cell, h_new = _slstm_cell(cell, gx, params["r_ifzo"].astype(
        jnp.float32), cfg.xlstm.num_heads)
    hf = h_new * jax.lax.rsqrt(
        jnp.mean(h_new * h_new, axis=-1, keepdims=True) + 1e-5)
    hn = (hf * params["out_norm"].astype(jnp.float32)).astype(cdt)
    g = hn @ _cast(params["ffn_gate"], cdt)
    u = hn @ _cast(params["ffn_up"], cdt)
    out = ((jax.nn.silu(g) * u) @ _cast(params["ffn_down"], cdt))[:, None, :]
    return out, (new_conv, new_cell)


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    k = cfg.xlstm.conv_kernel
    cdt = jnp.dtype(cfg.compute_dtype)
    zeros = jnp.zeros((batch, d), jnp.float32)
    return (jnp.zeros((batch, k - 1, d), cdt),
            (zeros, zeros, jnp.full((batch, d), -1e30, jnp.float32), zeros))
