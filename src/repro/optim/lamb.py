"""LAMB — layerwise adaptive large-batch optimization (You et al. 2019).

The HetSeq paper's stated future work: "adapting ongoing research in
distributed optimization (You et al. 2019) to further improve training
performance on heterogeneous infrastructure." Heterogeneous capacity
planning grows the *global* batch with the fleet (every extra node adds
rows), which is exactly the regime where Adam's fixed learning rate
breaks and LAMB's per-layer trust ratio

    p <- p - lr * phi(||p||) / ||update|| * update,
    update = m_hat / (sqrt(v_hat) + eps) + wd * p

keeps training stable. Shares Adam's moment state (and dtype policy /
ZeRO-1 sharding); selectable via OptimizerConfig(name="lamb") everywhere
Adam is.

Flat-view path (``HetConfig.overlap="buckets"``): ``apply_update_flat``
runs LAMB on the packed (num_buckets, bucket_elems) bucket stack. The
trust ratio is PER LAYER, and leaves span bucket boundaries, so —
unlike AdamW — LAMB cannot stream per-bucket updates as payloads land:
the per-leaf ||p|| / ||update|| norms are rebuilt over the whole stack
with segment sums keyed by ``core/buckets.py::segment_ids``. The train
step therefore always takes the barrier path (pipelined exchange, then
one flat update) when ``optimizer.name == "lamb"``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import adam


def apply_update(params: Any, grads: Any, state: adam.AdamState,
                 cfg: OptimizerConfig, lr: jnp.ndarray
                 ) -> Tuple[Any, adam.AdamState, Dict[str, jnp.ndarray]]:
    """One LAMB step (state-compatible with adam.AdamState)."""
    if cfg.grad_clip > 0:
        grads, gnorm = adam.clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = adam.global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    bc1, bc2 = adam.bias_corrections(cfg, step)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            update = update + cfg.weight_decay * pf
        # layerwise trust ratio: phi(||p||)/||u||, 1.0 when degenerate
        p_norm = jnp.linalg.norm(pf)
        u_norm = jnp.linalg.norm(update)
        trust = jnp.where((p_norm > 0) & (u_norm > 0),
                          p_norm / u_norm, 1.0)
        pf = pf - lr * trust * update
        return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype),
                trust)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    mean_trust = jnp.mean(jnp.stack([o[3] for o in out]))
    metrics = {"grad_norm": gnorm, "lr": lr, "trust_ratio": mean_trust}
    return new_p, adam.AdamState(step=step, m=new_m, v=new_v), metrics


def apply_update_flat(p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray,
                      v: jnp.ndarray, step: jnp.ndarray,
                      cfg: OptimizerConfig, lr: jnp.ndarray, *,
                      decay_mask: jnp.ndarray, seg_ids: jnp.ndarray,
                      num_leaves: int,
                      clip_scale: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                 jnp.ndarray]:
    """One LAMB step on the whole packed bucket stack.

    ``seg_ids`` maps every element to its source leaf (padding maps to
    ``num_leaves`` and gets trust 1, a no-op on zero padding). Returns
    (p', m', v', mean trust ratio over real leaves).
    """
    pf, update, mf, vf = adam.flat_adamw_terms(
        p, g, m, v, step, cfg, decay_mask=decay_mask,
        clip_scale=clip_scale)
    # per-leaf norms over the flat stream (leaves may span buckets)
    sid = seg_ids.reshape(-1)
    p_norm = jnp.sqrt(jax.ops.segment_sum(
        jnp.square(pf.reshape(-1)), sid, num_segments=num_leaves + 1))
    u_norm = jnp.sqrt(jax.ops.segment_sum(
        jnp.square(update.reshape(-1)), sid, num_segments=num_leaves + 1))
    trust = jnp.where((p_norm > 0) & (u_norm > 0), p_norm / u_norm, 1.0)
    pf = pf - lr * trust[sid].reshape(pf.shape) * update
    mean_trust = jnp.mean(trust[:num_leaves])
    return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype),
            mean_trust)
