"""LAMB — layerwise adaptive large-batch optimization (You et al. 2019).

The HetSeq paper's stated future work: "adapting ongoing research in
distributed optimization (You et al. 2019) to further improve training
performance on heterogeneous infrastructure." Heterogeneous capacity
planning grows the *global* batch with the fleet (every extra node adds
rows), which is exactly the regime where Adam's fixed learning rate
breaks and LAMB's per-layer trust ratio

    p <- p - lr * phi(||p||) / ||update|| * update,
    update = m_hat / (sqrt(v_hat) + eps) + wd * p

keeps training stable. Shares Adam's moment state (and dtype policy /
ZeRO-1 sharding); selectable via OptimizerConfig(name="lamb") everywhere
Adam is.

Flat-view path (``HetConfig.overlap`` in {"buckets", "backward"}):
``apply_update_flat`` runs LAMB on the packed (num_buckets,
bucket_elems) bucket stack. The trust ratio is PER LAYER and leaves
span bucket boundaries, but everything EXCEPT the final trust-scaled
step is per-element, so the barrier shrinks to one trailing pass: the
backward-overlap flush pipeline (``overlap="backward"``) streams the
m/v moment updates and the per-leaf squared-norm partials
(:func:`bucket_norm_terms`) per bucket as each reduced payload lands
mid-backprop, and defers only the trust-ratio application to ONE
trailing elementwise pass (:func:`apply_trust`) after the last bucket.
Bit-exactness contract: partials are combined across buckets in
canonical bucket-index order (a fixed python-loop fp reduction —
:func:`combine_norm_terms`), and ``apply_update_flat`` itself computes
its norms through the same per-bucket calls in the same order, so the
streamed hooks and the whole-stack barrier form are bitwise identical
by construction given the same reduced stack (tests/test_overlap.py).
The whole-stack barrier form still runs (a) when ``grad_clip > 0`` —
the clip factor needs every bucket BEFORE the moment update — and
(b) in the after-backward bucket engine (``overlap="buckets"``):
fusing LAMB's hook into that engine's per-bucket scan measurably
perturbs how XLA compiles the whole-module gradient program (~0.4% of
reduced-grad elements move 1 ulp, stable across every hook variant
tried), which would break the backward==buckets bitwise contract; its
exchange is already fully overlapped bucket-to-bucket, so the barrier
there costs only the trailing optimizer pass.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import adam


def apply_update(params: Any, grads: Any, state: adam.AdamState,
                 cfg: OptimizerConfig, lr: jnp.ndarray
                 ) -> Tuple[Any, adam.AdamState, Dict[str, jnp.ndarray]]:
    """One LAMB step (state-compatible with adam.AdamState)."""
    if cfg.grad_clip > 0:
        grads, gnorm = adam.clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = adam.global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    bc1, bc2 = adam.bias_corrections(cfg, step)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            update = update + cfg.weight_decay * pf
        # layerwise trust ratio: phi(||p||)/||u||, 1.0 when degenerate
        p_norm = jnp.linalg.norm(pf)
        u_norm = jnp.linalg.norm(update)
        trust = jnp.where((p_norm > 0) & (u_norm > 0),
                          p_norm / u_norm, 1.0)
        pf = pf - lr * trust * update
        return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype),
                trust)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    mean_trust = jnp.mean(jnp.stack([o[3] for o in out]))
    metrics = {"grad_norm": gnorm, "lr": lr, "trust_ratio": mean_trust}
    return new_p, adam.AdamState(step=step, m=new_m, v=new_v), metrics


def bucket_norm_terms(pf: jnp.ndarray, update: jnp.ndarray,
                      seg_ids: jnp.ndarray, num_leaves: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ONE bucket's per-leaf squared-norm partials.

    ``pf``/``update``/``seg_ids`` are matching (bucket_elems,) slices;
    returns (p_ssq, u_ssq), each (num_leaves + 1,) — element ``i`` is
    this bucket's contribution to leaf i's squared ||p|| / ||update||
    (index ``num_leaves`` collects the zero padding). The streamed
    overlap hooks emit these as each bucket lands.
    """
    sid = seg_ids.reshape(-1)
    p_ssq = jax.ops.segment_sum(
        jnp.square(pf.reshape(-1)), sid, num_segments=num_leaves + 1)
    u_ssq = jax.ops.segment_sum(
        jnp.square(update.reshape(-1)), sid, num_segments=num_leaves + 1)
    return p_ssq, u_ssq


def combine_norm_terms(rows) -> jnp.ndarray:
    """Sum per-bucket partials in canonical bucket-index order.

    ``rows``: a (num_buckets, num_leaves + 1) stack or a list of
    (num_leaves + 1,) rows. A fixed python-loop fp reduction order —
    NOT jnp.sum, whose reduction tree XLA may reassociate — is the
    bit-exactness contract between the streamed and whole-stack paths:
    both combine the identical per-bucket partials in the identical
    order, whatever order the buckets were flushed in.
    """
    rows = list(rows)
    total = rows[0]
    for row in rows[1:]:
        total = total + row
    return total


def trust_from_norms(p_ssq: jnp.ndarray, u_ssq: jnp.ndarray
                     ) -> jnp.ndarray:
    """Per-leaf trust ratios from combined squared norms (1.0 when
    degenerate — including the padding segment)."""
    p_norm, u_norm = jnp.sqrt(p_ssq), jnp.sqrt(u_ssq)
    return jnp.where((p_norm > 0) & (u_norm > 0), p_norm / u_norm, 1.0)


def apply_trust(pf: jnp.ndarray, update: jnp.ndarray, lr: jnp.ndarray,
                seg_ids: jnp.ndarray, trust: jnp.ndarray) -> jnp.ndarray:
    """The single trailing elementwise pass: trust-scaled step on the
    (already moment-updated) fp32 params. Shapes of ``pf``/``update``/
    ``seg_ids`` must match (one bucket or the whole stack)."""
    sid = seg_ids.reshape(-1)
    return pf - lr * trust[sid].reshape(pf.shape) * update


def apply_update_flat(p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray,
                      v: jnp.ndarray, step: jnp.ndarray,
                      cfg: OptimizerConfig, lr: jnp.ndarray, *,
                      decay_mask: jnp.ndarray, seg_ids: jnp.ndarray,
                      num_leaves: int,
                      clip_scale: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                 jnp.ndarray]:
    """One LAMB step on the whole packed bucket stack.

    ``seg_ids`` maps every element to its source leaf (padding maps to
    ``num_leaves`` and gets trust 1, a no-op on zero padding). On a
    2-D (num_buckets, bucket_elems) stack the per-leaf norms are
    computed through the same per-bucket ``bucket_norm_terms`` calls
    the streamed overlap hooks make, combined in bucket-index order —
    so this barrier form and the streamed form are bitwise identical.
    Returns (p', m', v', mean trust ratio over real leaves).
    """
    pf, update, mf, vf = adam.flat_adamw_terms(
        p, g, m, v, step, cfg, decay_mask=decay_mask,
        clip_scale=clip_scale)
    if pf.ndim == 2:
        parts = [bucket_norm_terms(pf[k], update[k], seg_ids[k],
                                   num_leaves)
                 for k in range(pf.shape[0])]
        p_ssq = combine_norm_terms([pp for pp, _ in parts])
        u_ssq = combine_norm_terms([uu for _, uu in parts])
    else:
        p_ssq, u_ssq = bucket_norm_terms(pf, update, seg_ids, num_leaves)
    trust = trust_from_norms(p_ssq, u_ssq)
    pf = apply_trust(pf, update, lr, seg_ids, trust)
    mean_trust = jnp.mean(trust[:num_leaves])
    return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype),
            mean_trust)
