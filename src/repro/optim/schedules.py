"""Learning-rate schedules (paper: per-GPU scheduler, identical states).

inverse_sqrt — the paper's transformer/translation schedule;
linear       — the paper's BERT schedule (warmup then linear decay);
cosine, constant — common extras.
All are pure functions of the (global) step, so every replica computes
the same lr without communication.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def learning_rate(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    warm = jnp.maximum(float(cfg.warmup_steps), 1.0)
    total = jnp.maximum(float(cfg.total_steps), warm + 1.0)
    if cfg.schedule == "inverse_sqrt":
        # fairseq inverse_sqrt: linear warmup, then lr * sqrt(warm / s)
        lr = cfg.lr * jnp.minimum(s / warm, jnp.sqrt(warm / s))
    elif cfg.schedule == "linear":
        decay = jnp.clip((total - s) / (total - warm), 0.0, 1.0)
        lr = cfg.lr * jnp.minimum(s / warm, decay)
    elif cfg.schedule == "cosine":
        frac = jnp.clip((s - warm) / (total - warm), 0.0, 1.0)
        lr = cfg.lr * jnp.minimum(s / warm,
                                  0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    elif cfg.schedule == "constant":
        lr = cfg.lr * jnp.minimum(s / warm, 1.0)
    else:
        raise ValueError(cfg.schedule)
    return lr
