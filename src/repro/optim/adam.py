"""Adam / AdamW as pure pytree transforms (paper: per-GPU optimizer).

Each replica holds (conceptually) its own optimizer initialized with the
same state — in SPMD that is one optimizer whose states are sharded like
the parameters (ZeRO-1 when params are FSDP-sharded). Moment dtypes are
configurable per architecture (``m_dtype``/``v_dtype``): arctic-480b
stores m in bf16 so the optimizer state fits 16 GB HBM per chip.

All math accumulates in fp32 regardless of storage dtype.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class AdamState(NamedTuple):
    step: jnp.ndarray              # () int32
    m: Any                         # pytree like params
    v: Any


def init_state(params: Any, cfg: OptimizerConfig) -> AdamState:
    m = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)), params)
    v = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_update(params: Any, grads: Any, state: AdamState,
                 cfg: OptimizerConfig, lr: jnp.ndarray
                 ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (params', state', metrics)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:    # decay matrices only
            update = update + cfg.weight_decay * pf
        pf = pf - lr * update
        return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step=step, m=new_m, v=new_v), metrics
