"""Adam / AdamW as pure pytree transforms (paper: per-GPU optimizer).

Each replica holds (conceptually) its own optimizer initialized with the
same state — in SPMD that is one optimizer whose states are sharded like
the parameters (ZeRO-1 when params are FSDP-sharded). Moment dtypes are
configurable per architecture (``m_dtype``/``v_dtype``): arctic-480b
stores m in bf16 so the optimizer state fits 16 GB HBM per chip.

Flat-view path (``HetConfig.overlap="buckets"``): ``apply_update_flat``
runs the same elementwise AdamW math on packed
(num_buckets, bucket_elems) views of params/m/v (core/buckets.py
layout), one bucket slice at a time, so the train step can fuse the
update for bucket *k* into the reduction pipeline the moment bucket
*k*'s reduced payload lands. The per-leaf decay-matrices-only rule
travels as a packed ``decay_mask``; ``init_state_flat`` builds the
moments directly in the packed layout. The elementwise math is
identical to ``apply_update``, so (fp32, no clip) the fused pipeline is
bit-identical to the monolithic tree update.

All math accumulates in fp32 regardless of storage dtype.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class AdamState(NamedTuple):
    step: jnp.ndarray              # () int32
    m: Any                         # pytree like params
    v: Any


def init_state(params: Any, cfg: OptimizerConfig) -> AdamState:
    m = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)), params)
    v = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def init_state_flat(num_buckets: int, bucket_elems: int,
                    cfg: OptimizerConfig) -> AdamState:
    """Zero moments in the packed (num_buckets, bucket_elems) layout."""
    m = jnp.zeros((num_buckets, bucket_elems), jnp.dtype(cfg.m_dtype))
    v = jnp.zeros((num_buckets, bucket_elems), jnp.dtype(cfg.v_dtype))
    return AdamState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def bias_corrections(cfg: OptimizerConfig, step: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b1, b2 = cfg.betas
    sf = step.astype(jnp.float32)
    return 1.0 - b1 ** sf, 1.0 - b2 ** sf


def flat_adamw_terms(p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray,
                     v: jnp.ndarray, step: jnp.ndarray,
                     cfg: OptimizerConfig, *,
                     decay_mask: jnp.ndarray,
                     clip_scale: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                jnp.ndarray]:
    """The shared elementwise AdamW math on packed views.

    Returns (pf, update, mf, vf) in fp32 — the caller applies its own
    step rule (plain ``pf - lr * update`` for AdamW, trust-ratio-scaled
    for LAMB) so the moment/decay math lives in exactly one place.
    """
    bc1, bc2 = bias_corrections(cfg, step)
    b1, b2 = cfg.betas
    gf = g.astype(jnp.float32)
    if clip_scale is not None:
        gf = gf * clip_scale
    mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
    vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
    update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
    pf = p.astype(jnp.float32)
    if cfg.weight_decay > 0:
        update = update + (cfg.weight_decay *
                           decay_mask.astype(jnp.float32) * pf)
    return pf, update, mf, vf


def apply_update_flat(p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray,
                      v: jnp.ndarray, step: jnp.ndarray,
                      cfg: OptimizerConfig, lr: jnp.ndarray, *,
                      decay_mask: jnp.ndarray,
                      clip_scale: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One AdamW step on a packed bucket view (any shape, elementwise).

    ``p``/``g``/``m``/``v``/``decay_mask`` are matching slices of the
    flat bucket layout — one (bucket_elems,) bucket inside the fused
    reduction pipeline, or the whole (num_buckets, bucket_elems) stack
    on the clip-barrier path. ``step`` is the post-increment step (the
    caller advances it once per train step, not per bucket).
    ``clip_scale`` is the global-norm clip factor, precomputed by the
    caller because it needs every bucket's reduced payload — with
    ``grad_clip == 0`` pass None and the update is exactly
    ``apply_update``'s elementwise math. Bucket padding stays zero by
    construction (zero grads, zero moments, mask zero).

    Returns (p', m', v') with storage dtypes preserved.
    """
    pf, update, mf, vf = flat_adamw_terms(
        p, g, m, v, step, cfg, decay_mask=decay_mask,
        clip_scale=clip_scale)
    pf = pf - lr * update
    return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_update(params: Any, grads: Any, state: AdamState,
                 cfg: OptimizerConfig, lr: jnp.ndarray
                 ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (params', state', metrics)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    bc1, bc2 = bias_corrections(cfg, step)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:    # decay matrices only
            update = update + cfg.weight_decay * pf
        pf = pf - lr * update
        return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step=step, m=new_m, v=new_v), metrics
