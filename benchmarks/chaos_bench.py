"""Convergence-under-faults benchmark (fail-loud) -> BENCH_chaos.json.

Runs a small fp32 model through scripted fault scenarios
(core/chaos.py presets) with the REAL live-heterogeneity machinery —
capacity plans, pack/unpack, straggler monitor with chaos-modeled
per-rank times, soft replans, RemeshRequired escalation through
``elastic.plan_remesh``, and v3 checkpoint save/rollback-restore (with
injected transient ckpt IO faults exercising the writer's bounded
retry) — and asserts two invariants, loudly:

(a) **Bit-identity.** The executor computes per-row gradients (vmap)
    and aggregates them in canonical global-row order
    (``weighting.canonical_aggregate``), which removes the row->rank
    assignment from the float math: fp32 addition is not associative,
    so the SPMD step's aggregate is only tolerance-equal across plans,
    but the canonical sum has a FIXED reduction tree. Under it, a
    chaos-disturbed run — replans shifting rows between ranks, a dead
    rank drained to zero rows, a pod kill escalating to re-mesh +
    checkpoint rollback — must produce the bit-identical per-step loss
    sequence and final params as the undisturbed run consuming the same
    global rows. Any drift means the machinery corrupted the consumed
    row stream (lost/duplicated/reordered rows, inexact restore).

(b) **Replanning pays.** Under the sustained-slowdown preset, modeled
    wall-clock (max over alive ranks of rows/speed * slowdown, per
    step) with throughput-fed replanning must be STRICTLY below the
    no-replan baseline.

Plus a replayability check: the same seed + schedule produces a
byte-identical modeled trace and a bit-identical second training run.

Quick mode (benchmarks/run.py --quick) runs the three core presets at
reduced step counts; the full tier adds the combined "storm" preset
(slowdown + flaky reports + pod kill + ckpt IO faults).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import sys
import tempfile
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.abspath(__file__)), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base as cfgbase
from repro.configs.base import OptimizerConfig
from repro.core import chaos, dummy, elastic, weighting
from repro.core import capacity as cap
from repro.core.straggler import RemeshRequired, StragglerMonitor
from repro.data.synthetic import make_lm_records
from repro.launch import steps as steps_mod  # noqa: F401 (parity import)
from repro.models.model import build_model
from repro.optim import adam

GLOBAL_ROWS = 12
SEQ_LEN = 12
POOL_SEQS = 64
TOPO = elastic.MeshTopology(pods=2, data_per_pod=2, model=1)
HEADROOM = 1.5          # buffer 5/rank: 2 survivors (10) < 12 rows =>
CKPT_EVERY = 3          # a pod kill MUST escalate to a re-mesh


def _build():
    cfg = dataclasses.replace(
        cfgbase.smoke_config("tinyllama-1.1b"), compute_dtype="float32",
        num_layers=1, d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
        vocab_size=64)
    model = build_model(cfg)
    params = jax.jit(model.init_params)(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-2, grad_clip=0.0)
    opt = adam.init_state(params, ocfg)

    @jax.jit
    def step_fn(params, opt, batch):
        (o, w), grads = weighting.per_row_values(model.loss_fn, params,
                                                 batch)
        loss, g, _, _ = weighting.canonical_aggregate(o, w, grads)
        new_p, new_opt, _ = adam.apply_update(params, g, opt, ocfg,
                                              jnp.float32(ocfg.lr))
        return new_p, new_opt, loss

    pool = make_lm_records(POOL_SEQS, SEQ_LEN, cfg.vocab_size, seed=7)
    return params, opt, step_fn, pool


def _rows_for_step(pool: Dict[str, np.ndarray], step: int
                   ) -> Dict[str, np.ndarray]:
    """The global rows of one step — a pure function of the step index,
    so every run (disturbed or not, any plan) consumes the same rows."""
    idx = [(step * GLOBAL_ROWS + j) % POOL_SEQS
           for j in range(GLOBAL_ROWS)]
    return {"inputs": pool["inputs"][idx], "labels": pool["labels"][idx]}


def _run(schedule: chaos.ChaosSchedule, steps: int, params, opt,
         step_fn, pool, replan: bool = True, replan_interval: int = 2,
         ckpt_dir: Optional[str] = None) -> Dict:
    """One training run under a chaos schedule. Returns the per-step
    loss bits, final params, modeled wall-clock and event counters."""
    topo = TOPO
    plan = cap.plan_capacities(GLOBAL_ROWS, [1.0] * topo.dp_size,
                               headroom=HEADROOM)
    engine = chaos.ChaosEngine(schedule, topo.dp_size,
                               topo.data_per_pod)
    monitor = StragglerMonitor(num_ranks=topo.dp_size, ema_decay=0.6,
                               replan_interval=replan_interval,
                               dead_timeout_steps=2)
    mgr = (CheckpointManager(ckpt_dir, keep=2, io_retries=3,
                             io_backoff_s=0.005,
                             fault_hook=engine.ckpt_fault_hook())
           if ckpt_dir else None)
    losses: Dict[int, bytes] = {}
    wall = 0.0
    soft_replans = 0
    remeshes = 0
    first_replan_step = None
    s = 0
    while s < steps:
        samples = _rows_for_step(pool, s)
        # the REAL packing path: rows -> per-rank fixed buffers with
        # dummy padding under the CURRENT plan, then recovered to
        # global order for the canonical executor. A plan that loses,
        # duplicates or reorders rows breaks bit-identity right here.
        packed = dummy.pack_global_batch(samples, plan)
        rec = dummy.unpack_real_rows(packed, plan)
        batch = {k: jnp.asarray(v) for k, v in rec.items()}
        params, opt, loss = step_fn(params, opt, batch)
        losses[s] = np.float32(loss).tobytes()
        wall += engine.modeled_step_wall(s, plan.rows_per_rank)
        done = s + 1
        if mgr is not None and done % CKPT_EVERY == 0:
            mgr.save(done, {"params": params,
                            "opt": {"step": opt.step, "m": opt.m,
                                    "v": opt.v}},
                     meta={"plan": plan})
        monitor.observe(engine.step_times(s, plan.rows_per_rank, 1.0))
        if replan and monitor.should_replan():
            try:
                new_plan = monitor.replan(plan)
                if new_plan.rows_per_rank.tolist() != \
                        plan.rows_per_rank.tolist():
                    soft_replans += 1
                    if first_replan_step is None:
                        first_replan_step = s
                plan = new_plan
            except RemeshRequired:
                if mgr is None:
                    raise SystemExit(
                        "[chaos_bench] RemeshRequired without a "
                        "checkpoint dir — preset/topology mismatch")
                mgr.wait()
                dead = set(monitor.dead_ranks().tolist())
                dpp = topo.data_per_pod
                alive = [p for p in range(topo.pods)
                         if not all(r in dead
                                    for r in range(p * dpp,
                                                   (p + 1) * dpp))]
                decision = elastic.plan_remesh(topo, alive, GLOBAL_ROWS)
                if not decision.restart_required:
                    raise SystemExit(
                        "[chaos_bench] dead ranks without a whole pod "
                        "lost cannot be absorbed — bad preset")
                if not elastic.validate_resume_equivalence(
                        plan, decision.plan):
                    raise SystemExit(
                        "[chaos_bench] remesh plan consumes a "
                        "different global record stream")
                template = jax.tree.map(
                    np.asarray,
                    {"params": params, "opt": {"step": opt.step,
                                               "m": opt.m, "v": opt.v}})
                host, meta = mgr.restore(template)
                params = jax.tree.map(jnp.asarray, host["params"])
                opt = adam.AdamState(
                    step=jnp.asarray(host["opt"]["step"]),
                    m=jax.tree.map(jnp.asarray, host["opt"]["m"]),
                    v=jax.tree.map(jnp.asarray, host["opt"]["v"]))
                s = int(meta["step"])      # rollback: replay from ckpt
                topo = decision.topology
                plan = decision.plan
                engine = engine.after_remesh(alive)
                monitor = StragglerMonitor(
                    num_ranks=topo.dp_size, ema_decay=0.6,
                    replan_interval=replan_interval,
                    dead_timeout_steps=2)
                remeshes += 1
                continue
        s += 1
    if mgr is not None:
        mgr.wait()
    return {"losses": losses, "params": params, "wall": wall,
            "soft_replans": soft_replans, "remeshes": remeshes,
            "first_replan_step": first_replan_step,
            "final_ranks": plan.num_ranks,
            "final_rows": plan.rows_per_rank.tolist()}


def _bit_identical(ref: Dict, run: Dict) -> Tuple[bool, str]:
    if set(ref["losses"]) != set(run["losses"]):
        return False, "step coverage differs"
    for s in ref["losses"]:
        if ref["losses"][s] != run["losses"][s]:
            return False, f"loss bits differ at step {s}"
    ra = jax.tree.leaves(ref["params"])
    rb = jax.tree.leaves(run["params"])
    for a, b in zip(ra, rb):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype != b.dtype or not np.array_equal(
                a.view(np.uint8), b.view(np.uint8)):
            return False, "final params differ bitwise"
    return True, "bit-identical"


def main(quick: bool = False, out: str = "BENCH_chaos.json",
         seed: int = 0) -> Dict:
    params0, opt0, step_fn, pool = _build()
    n, dpp = TOPO.dp_size, TOPO.data_per_pod
    steps = {"slowdown": 10 if quick else 16,
             "dead-rank": 10 if quick else 16,
             "pod-kill": 14 if quick else 20,
             "storm": 18}
    presets = ["slowdown", "dead-rank", "pod-kill"]
    if not quick:
        presets.append("storm")

    results: Dict[str, Dict] = {}
    failures: List[str] = []
    tmp = tempfile.mkdtemp(prefix="chaos_bench_")
    try:
        for name in presets:
            t = steps[name]
            schedule = chaos.ChaosSchedule(
                events=chaos.PRESETS[name](n, dpp, t), seed=seed)
            needs_ckpt = any(ev.kind == "kill" and ev.pod is not None
                             for ev in schedule.events)
            if needs_ckpt and not any(ev.kind == "ckpt_io_fail"
                                      for ev in schedule.events):
                # exercise the writer's bounded retry on every preset
                # that checkpoints: each save fails once, then lands
                schedule = schedule.with_events(
                    chaos.ckpt_io_fail(step=None, fails=1))
            interval = 6 if name == "dead-rank" else 2
            ckpt_dir = (os.path.join(tmp, name.replace("-", "_"))
                        if needs_ckpt else None)
            ref = _run(chaos.ChaosSchedule(seed=seed), t, params0, opt0,
                       step_fn, pool, replan=False)
            run = _run(schedule, t, params0, opt0, step_fn, pool,
                       replan=True, replan_interval=interval,
                       ckpt_dir=ckpt_dir)
            ok, why = _bit_identical(ref, run)
            results[name] = {
                "steps": t, "bit_identical": ok, "detail": why,
                "soft_replans": run["soft_replans"],
                "remeshes": run["remeshes"],
                "first_replan_step": run["first_replan_step"],
                "final_ranks": run["final_ranks"],
                "final_rows": run["final_rows"],
                "modeled_wall": run["wall"],
                "modeled_wall_undisturbed": ref["wall"],
            }
            if not ok:
                failures.append(f"{name}: NOT bit-identical ({why})")
            print(f"[chaos_bench] {name}: bit_identical={ok} "
                  f"soft_replans={run['soft_replans']} "
                  f"remeshes={run['remeshes']} "
                  f"final_rows={run['final_rows']} "
                  f"wall={run['wall']:.1f} (undisturbed {ref['wall']:.1f})")

        # structural expectations per preset — a preset that silently
        # stops exercising its path is a dead test
        if results["dead-rank"]["soft_replans"] < 1 or \
                0 not in results["dead-rank"]["final_rows"]:
            failures.append("dead-rank: the dead rank was never "
                            "drained by a soft replan")
        # immediate replan (not the interval-6 boundary): the kill
        # lands at steps//3, timeout 2 => drain 2 steps later
        kill_at = steps["dead-rank"] // 3
        if results["dead-rank"]["first_replan_step"] != kill_at + 1:
            failures.append(
                f"dead-rank: replan at step "
                f"{results['dead-rank']['first_replan_step']}, expected "
                f"immediately on dead detection at {kill_at + 1}")
        if results["pod-kill"]["remeshes"] != 1 or \
                results["pod-kill"]["final_ranks"] != n // 2:
            failures.append("pod-kill: expected exactly one re-mesh to "
                            "half the DP width")

        # (b) modeled wall-clock: replanning strictly beats no-replan
        # under sustained slowdown
        t = steps["slowdown"]
        schedule = chaos.ChaosSchedule(
            events=chaos.PRESETS["slowdown"](n, dpp, t), seed=seed)
        with_replan = _run(schedule, t, params0, opt0, step_fn, pool,
                           replan=True, replan_interval=2)
        no_replan = _run(schedule, t, params0, opt0, step_fn, pool,
                         replan=False)
        wall_ok = with_replan["wall"] < no_replan["wall"]
        results["slowdown_wall"] = {
            "replan": with_replan["wall"],
            "no_replan": no_replan["wall"],
            "speedup": no_replan["wall"] / max(with_replan["wall"],
                                               1e-9),
            "strictly_better": wall_ok,
        }
        print(f"[chaos_bench] slowdown wall: replan "
              f"{with_replan['wall']:.1f} vs no-replan "
              f"{no_replan['wall']:.1f} "
              f"({results['slowdown_wall']['speedup']:.2f}x)")
        if not wall_ok:
            failures.append("slowdown: replanned modeled wall-clock is "
                            "not strictly below the no-replan baseline")

        # replayability: byte-identical modeled trace AND bit-identical
        # second training run from the same seed + schedule
        eng_a = chaos.ChaosEngine(schedule, n, dpp)
        eng_b = chaos.ChaosEngine(schedule, n, dpp)
        trace_ok = (json.dumps(eng_a.trace(t, [3] * n))
                    == json.dumps(eng_b.trace(t, [3] * n)))
        rerun = _run(schedule, t, params0, opt0, step_fn, pool,
                     replan=True, replan_interval=2)
        rerun_ok, rerun_why = _bit_identical(with_replan, rerun)
        results["replayable"] = {"trace": trace_ok,
                                 "training_run": rerun_ok}
        if not trace_ok:
            failures.append("chaos trace is not replayable from seed")
        if not rerun_ok:
            failures.append(f"repeated chaos run diverged "
                            f"({rerun_why})")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    record = {"quick": quick, "seed": seed,
              "global_rows": GLOBAL_ROWS,
              "topology": {"pods": TOPO.pods,
                           "data_per_pod": TOPO.data_per_pod},
              "presets": {k: v for k, v in results.items()
                          if k in steps},
              "slowdown_wall": results["slowdown_wall"],
              "replayable": results["replayable"]}
    with open(out, "w") as fh:
        # np.float64 walls / np.bool comparisons -> plain JSON scalars
        json.dump(record, fh, indent=1,
                  default=lambda o: o.item()
                  if isinstance(o, np.generic) else str(o))
    print(f"[chaos_bench] wrote {out}")
    if failures:
        for f in failures:
            print(f"[chaos_bench] INVARIANT BROKEN: {f}")
        raise SystemExit("[chaos_bench] fail-loud: "
                         + "; ".join(failures))
    return record


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
