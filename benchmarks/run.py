import os
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # the scaling benchmarks emulate the paper's multi-node grid on
    # host devices; 8 "nodes" like the paper's largest configuration
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

DOC = """Benchmark suite — one entry per paper table/figure + roofline.

  scaling_translation  paper Table 3, Translation block
  scaling_bert         paper Table 3, BERT block (masked-LM weights)
  scaling_small        paper Table 3, MNIST block (negative result)
  equivalence          the HetSeq invariant, measured
  roofline_bench       §Roofline table from dry-run artifacts
  reduce_bench         per-leaf vs bucketed gradient reduction (--quick
                       smoke: fails loudly if the bucketed engine's
                       cross-pod collective count or modeled int8 DCN
                       bytes regress)
  overlap_bench        monolithic vs double-buffered per-bucket fused
                       reduce+update pipeline (--quick smoke: fails
                       loudly if the modeled overlapped step time is
                       not strictly below the serial modeled time, or
                       the fused pipeline diverges from the monolithic
                       update)
  chaos_bench          convergence under scripted faults (core/chaos.py
                       presets: sustained slowdown, dead rank, pod kill
                       + re-mesh, full storm): fails loudly if a
                       chaos-disturbed run is not bit-identical (fp32,
                       canonical-order aggregation) to the undisturbed
                       run over the same global rows, if throughput-fed
                       replanning does not strictly beat no-replan on
                       modeled wall-clock under sustained slowdown, or
                       if the seeded trace/run is not replayable
  serve_bench          continuous-batching serving engine (repro/serve:
                       paged KV cache, capacity-aware admission): fails
                       loudly if the engine's modeled tokens/sec is not
                       strictly above the static-batch baseline on the
                       same mixed-length open-loop trace, if a single
                       sequence's generated tokens are not bit-identical
                       to the contiguous-cache static path (fp32), or if
                       per-pod peak concurrency under saturation is not
                       the capacity-plan split (slower pods strictly
                       fewer sequences); includes a 3-arrival
                       mixed-length end-to-end smoke and a decode-step
                       roofline: the in-kernel-gather byte model of the
                       paged Pallas kernels (attention_impl="pallas")
                       must be strictly below materialize-then-attend
                       at every swept (max_blocks, block_size) point,
                       and the pallas engine must be token-identical to
                       the reference engine on the smoke trace
  pipeline_bench       heterogeneous pipeline parallelism
                       (HetConfig.pipeline_stages: capacity-sized
                       contiguous stages + 1F1B): fails loudly if the
                       stages=2 step is not bit-identical (fp32,
                       allreduce, clip=0) to pure DP, if the modeled
                       capacity-sized stage cut does not strictly beat
                       uniform stages AND pure DP on a 2:1 pod-speed
                       skew, or if a checkpoint saved under one stage
                       plan does not restore bit-identically into a
                       different stage plan
  durability_smoke     (--quick only) checkpoint manifest path: save ->
                       corrupt a shard / delete the manifest ->
                       checksum-validated fallback restore to the
                       previous committed step

--quick: the CI smoke tier — runs the fail-loud reduce/overlap/chaos
bench smokes plus the repo's quick test tier (``pytest -m "not slow"``: the
multi-device subprocess suites, hypothesis sweeps and driver
integration tests carry a ``slow`` marker and stay in the full tier-1
run), skipping the scaling sweeps.

Prints a ``name,us_per_call,derived`` CSV summary at the end.
"""

import argparse
import os
import subprocess
import sys
import time


def _run_quick_test_tier() -> float:
    """The -m 'not slow' pytest tier, as CI runs it. Fails loudly."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
         "-p", "no:cacheprovider", os.path.join(repo, "tests")],
        env=env, cwd=repo)
    if proc.returncode != 0:
        raise SystemExit(f"quick test tier failed "
                         f"(exit {proc.returncode})")
    return time.time() - t0


def main() -> None:
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fail-loud bench smokes + the "
                         "-m 'not slow' pytest tier, no scaling sweeps")
    args = ap.parse_args()

    t_all = time.time()
    csv = []

    from benchmarks import (chaos_bench, equivalence, overlap_bench,
                            pipeline_bench, reduce_bench,
                            roofline_bench, scaling_bert,
                            scaling_small, scaling_translation,
                            serve_bench)

    rb = reduce_bench.main(quick=True)
    csv.append(("reduce_bench", rb["bucketed"]["avg_ms"] * 1e3,
                f"collectives_bucketed={rb['bucketed']['collectives']} "
                f"vs_per_leaf={rb['per_leaf']['collectives']}"))

    ob = overlap_bench.main(quick=True)
    csv.append(("overlap_bench", ob["fp32"]["overlap"]["avg_ms"] * 1e3,
                f"model_speedup_int8="
                f"{ob['int8']['model']['model_speedup']:.2f}x "
                f"bwd_overlap_int8="
                f"{ob['backward_int8']['model']['model_speedup_vs_after_backward']:.2f}x "
                f"exact_fp32={ob['fp32']['exact_match']}"))

    cb = chaos_bench.main(quick=args.quick)
    n_bit = sum(1 for p in cb["presets"].values()
                if p["bit_identical"])
    csv.append(("chaos_bench", 0.0,
                f"bit_identical_presets={n_bit}/{len(cb['presets'])} "
                f"replan_speedup="
                f"{cb['slowdown_wall']['speedup']:.2f}x"))

    pb = pipeline_bench.main(quick=args.quick)
    csv.append(("pipeline_bench", 0.0,
                f"exact_fp32={pb['exactness']['exact_match']} "
                f"capacity_vs_uniform="
                f"{pb['modeled']['speedup_vs_uniform']:.2f}x "
                f"vs_dp={pb['modeled']['speedup_vs_dp']:.2f}x "
                f"restore_bit_identical="
                f"{pb['restore']['bit_identical']}"))

    sv = serve_bench.main(quick=args.quick)
    rf = sv["decode_roofline"]
    csv.append(("serve_bench", 0.0,
                f"continuous_vs_static="
                f"{sv['throughput']['speedup']:.2f}x "
                f"bit_identical={sv['bit_identity']['identical']} "
                f"pod_limits={sv['routing']['pod_limits']} "
                f"block_util_peak={sv['block_util']['peak']:.2f} "
                f"roofline_kernel_beats_materialize="
                f"{rf['kernel_strictly_better']} "
                f"pallas_token_identical="
                f"{rf['measured']['token_identical']}"))

    if args.quick:
        from benchmarks import docs_smoke, durability_smoke
        n_faults = durability_smoke.run_durability_smoke()
        csv.append(("durability_smoke", 0.0,
                    f"fault_scenarios={n_faults}"))
        n_cmds = docs_smoke.run_docs_smoke()
        csv.append(("docs_smoke", 0.0, f"readme_commands={n_cmds}"))
        tier_s = _run_quick_test_tier()
        csv.append(("quick_test_tier", 0.0, f"wall_s={tier_s:.1f}"))
    else:
        res = scaling_translation.main(max_nodes=8, steps=10)
        base = res[0]
        best = min(res, key=lambda r: r.avg_step_s)
        csv.append(("scaling_translation", base.avg_step_s * 1e6,
                    f"best_speedup={base.total_s / best.total_s:.2f}x"))

        res = scaling_bert.main(max_nodes=8, steps=10)
        base = res[0]
        best = min(res, key=lambda r: r.avg_step_s)
        csv.append(("scaling_bert", base.avg_step_s * 1e6,
                    f"best_speedup={base.total_s / best.total_s:.2f}x"))

        res = scaling_small.main(max_nodes=8, steps=8)
        base = res[0]
        worst = max(res[1:], key=lambda r: r.avg_step_s) if len(res) > 1 \
            else base
        csv.append(("scaling_small", base.avg_step_s * 1e6,
                    f"overhead_at_scale="
                    f"{worst.avg_step_s / base.avg_step_s:.2f}x"))

        rows = equivalence.main(trials=6)
        worst_g = max(r[2] for r in rows)
        csv.append(("equivalence", 0.0, f"max_grad_err={worst_g:.2e}"))

        rl = roofline_bench.main()
        if rl:
            import numpy as np
            fr = [r.roofline_frac for r in rl if r.kind == "train"]
            csv.append(("roofline", 0.0,
                        f"train_cells={len(fr)} median_roofline="
                        f"{100 * float(np.median(fr)):.1f}%"))

    print("\n== CSV summary (name,us_per_call,derived) ==")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")
    print(f"[benchmarks] total {time.time() - t_all:.1f}s")


if __name__ == '__main__':
    main()
