"""Checkpoint durability smoke — save -> corrupt -> fallback restore.

Dry-run sized (a few-KB synthetic state, no model, no mesh): writes two
committed v3 per-host-sharded checkpoints, then for each fault class —
truncated shard file, flipped payload byte, deleted manifest.json —
verifies that restore rejects the newest step via the manifest
validation (sizes + sha256 content checksums) and falls back to the
previous ``_DONE``-committed step, and that an explicitly requested
corrupt step raises. Runs in ``benchmarks/run.py --quick`` so the CI
smoke tier exercises the manifest path on every change.
"""
from __future__ import annotations

import os
import shutil
import tempfile

FAULTS = ("truncate_shard", "flip_byte", "delete_manifest")


def _corrupt(step_dir: str, fault: str) -> None:
    shard = os.path.join(step_dir, "arrays_host1.npz")
    if fault == "truncate_shard":
        size = os.path.getsize(shard)
        with open(shard, "rb+") as fh:
            fh.truncate(size // 2)
    elif fault == "flip_byte":
        with open(shard, "rb+") as fh:
            data = bytearray(fh.read())
            data[len(data) // 2] ^= 0xFF
            fh.seek(0)
            fh.write(bytes(data))
    elif fault == "delete_manifest":
        os.remove(os.path.join(step_dir, "manifest.json"))
    else:
        raise ValueError(fault)


def run_durability_smoke() -> int:
    """Exercise every fault class; returns the scenario count. Raises
    ``SystemExit`` on the first broken invariant."""
    import numpy as np

    from repro.checkpoint import repack
    from repro.checkpoint.checkpoint import (CheckpointCorruptError,
                                             CheckpointManager)

    def state(seed):
        r = np.random.default_rng(seed)
        return {"w": r.standard_normal((64, 8)).astype(np.float32),
                "b": r.standard_normal(512).astype(np.float32)}

    fmt = {"version": repack.FORMAT_VERSION, "hosts": 2,
           "packed_fields": [], "layout": None, "overlap": "none"}
    for fault in FAULTS:
        d = tempfile.mkdtemp(prefix="hetseq_durability_")
        try:
            mgr = CheckpointManager(d, keep=5)
            s1, s2 = state(1), state(2)
            mgr.save(1, s1, meta={"format": dict(fmt)}, block=True)
            mgr.save(2, s2, meta={"format": dict(fmt)}, block=True)
            _corrupt(os.path.join(d, "step_0000000002"), fault)
            try:
                mgr.restore(state(0), step=2)
            except CheckpointCorruptError:
                pass
            else:
                raise SystemExit(
                    f"durability smoke: explicit restore of the "
                    f"corrupted step ({fault}) did not raise")
            got, meta = mgr.restore(state(0))
            if meta["step"] != 1:
                raise SystemExit(
                    f"durability smoke: fallback after {fault} landed "
                    f"on step {meta['step']}, expected 1")
            if not (np.array_equal(got["w"], s1["w"])
                    and np.array_equal(got["b"], s1["b"])):
                raise SystemExit(
                    f"durability smoke: fallback restore after {fault} "
                    f"is not bit-identical to the committed step 1")
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return len(FAULTS)


if __name__ == "__main__":
    n = run_durability_smoke()
    print(f"[durability_smoke] {n} fault scenario(s) ok")
