"""README quickstart smoke — documented commands must stay runnable.

Parses every fenced code block in README.md, extracts the documented
``repro.launch.train`` invocations (joining backslash continuations),
and executes each one in ``--dry-run`` form: the driver builds the
mesh, capacity plan and full config stack and runs the same validation
``build_train_step`` does, then exits before compiling anything. A
renamed CLI flag, a removed config mode, or a documented-but-invalid
config combination fails the ``benchmarks/run.py --quick`` tier
loudly instead of rotting in the docs.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")
_TRAIN_MODULE = "repro.launch.train"


def quickstart_commands(readme_path: str = README) -> List[List[str]]:
    """Documented train-driver invocations, one token list each."""
    with open(readme_path) as fh:
        text = fh.read()
    blocks = re.findall(r"```[a-z]*\n(.*?)```", text, flags=re.S)
    commands: List[List[str]] = []
    for block in blocks:
        # join backslash-continued lines before tokenizing
        joined = re.sub(r"\\\s*\n\s*", " ", block)
        for line in joined.splitlines():
            line = line.strip()
            if _TRAIN_MODULE not in line or line.startswith("#"):
                continue
            tokens = line.split()
            args = tokens[tokens.index(_TRAIN_MODULE) + 1:]
            commands.append(args)
    return commands


def run_docs_smoke(readme_path: str = README) -> int:
    """Execute every quickstart command with ``--dry-run``; returns the
    number of commands checked. Raises on the first failure."""
    commands = quickstart_commands(readme_path)
    if not commands:
        raise SystemExit(
            f"docs smoke: no '{_TRAIN_MODULE}' commands found in "
            f"{readme_path} — the README quickstart must document at "
            f"least one runnable invocation")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    for args in commands:
        argv = [sys.executable, "-m", _TRAIN_MODULE] + args
        if "--dry-run" not in args:
            argv.append("--dry-run")
        proc = subprocess.run(argv, capture_output=True, text=True,
                              env=env, cwd=REPO, timeout=600)
        if proc.returncode != 0:
            raise SystemExit(
                f"docs smoke: README command failed "
                f"(exit {proc.returncode}):\n  {' '.join(argv)}\n"
                f"{proc.stderr[-2000:]}")
    return len(commands)


if __name__ == "__main__":
    n = run_docs_smoke()
    print(f"[docs_smoke] {n} README quickstart command(s) ok")
