"""Overlap benchmark: monolithic vs double-buffered per-bucket pipeline.

Measures, on the 8-host-device mesh (2 pods x 2 data x 2 model), the
fused reduce+update schedules wired behind ``HetConfig.overlap``:

  serial   — monolithic: pack -> 2-collective exchange
             (core/buckets.py::exchange_buckets) -> one flat AdamW
             update over the whole stack; link and compute take turns.
  overlap  — double-buffered pipeline
             (core/buckets.py::exchange_buckets_overlapped): bucket
             k+1's quantize/pack runs while bucket k's exchange is in
             flight, and the per-bucket flat-view AdamW update
             (optim/adam.py::apply_update_flat) is fused into the
             pipeline the moment each bucket lands.

For each mode it reports the measured wall time on the host mesh plus a
**modeled pipeline timeline**: per-bucket link occupancy comes from the
analytic byte models (``modeled_bucket_link_bytes``, the native-DCN
schedule) at an assumed DCN bandwidth, and per-bucket compute occupancy
(send-side pack/quantize, landing-side optimizer) from an assumed HBM
bandwidth on the touched bytes. The modeled serial time is the sum of
all three legs over all buckets; the modeled overlapped time is the
standard 3-stage pipeline recurrence

    prep_done[k] = prep_done[k-1] + t_prep[k]
    link_done[k] = max(link_done[k-1], prep_done[k]) + t_link[k]
    upd_done[k]  = max(upd_done[k-1], link_done[k]) + t_upd[k]

whose total approaches max(compute, link) instead of their sum as the
bucket count grows. The CPU host mesh executes collectives eagerly and
cannot actually overlap, so MEASURED wall time is reported for both
modes but the acceptance invariant is on the model (checked loudly in
``--quick`` and on every full run): modeled overlapped step time must
be strictly below modeled serial, and the fused pipeline must be
bit-identical (fp32) to the monolithic reduce+update.

Backward overlap (``HetConfig.overlap="backward"``): a third schedule
flushes buckets DURING backprop — each bucket's exchange is issued the
moment its last contributing layer's cotangent lands
(core/buckets.py::bucket_readiness + BucketFlushPipeline). The bench
builds a synthetic LAYERED gradient tree (head / stacked layers /
embedding, the uniform-stack partition), derives the readiness
schedule, and models the bwd+link timeline: per-stage backward compute
from HBM-touched bytes, per-bucket link occupancy gated on the
bucket's readiness stage. The acceptance invariant is that the modeled
backward-overlap step time is STRICTLY below the after-backward
("buckets") pipeline — the link works while the backward still
computes instead of idling through it — and that the flush-ordered
pipeline is bit-identical to the monolithic exchange (readiness order
must not change values).

Emits ``BENCH_overlap.json`` (``--out`` to relocate).
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.reduce_bench import count_pod_collectives, \
    synthetic_grad_tree
from repro import compat
from repro.configs.base import OptimizerConfig
from repro.core import buckets as bkt
from repro.launch import steps as steps_mod
from repro.optim import adam

_BLOCK = steps_mod._BLOCK

# modeled fabric: 100 Gb/s DCN (the slow heterogeneous link the paper's
# campus Ethernet maps to) and one HBM-class memory system feeding the
# pack/quantize and optimizer legs
DCN_BYTES_PER_S = 12.5e9
HBM_BYTES_PER_S = 900e9


def modeled_timeline(layout: bkt.BucketLayout, ranks: int, *,
                     compress: bool, block_size: int = _BLOCK
                     ) -> Dict[str, Any]:
    """Per-bucket 3-stage pipeline model (prep | link | update)."""
    nb = layout.num_buckets
    bucket_f32 = layout.bucket_elems * 4
    # send-side leg: read the raw bucket (+ error state and int8 write
    # for the compressed path); landing-side: AdamW touches p/m/v
    # read+write plus the reduced gradient read = 7 bucket-sized passes
    prep_passes = 3.0 if compress else 1.0
    t_prep = [prep_passes * bucket_f32 / HBM_BYTES_PER_S] * nb
    t_link = [bkt.modeled_bucket_link_bytes(
        layout, ranks, k, compress=compress, block_size=block_size)
        / DCN_BYTES_PER_S for k in range(nb)]
    t_upd = [7.0 * bucket_f32 / HBM_BYTES_PER_S] * nb

    timeline = []
    prep_done = link_done = upd_done = 0.0
    for k in range(nb):
        prep_start = prep_done
        prep_done = prep_start + t_prep[k]
        link_start = max(link_done, prep_done)
        link_done = link_start + t_link[k]
        upd_start = max(upd_done, link_done)
        upd_done = upd_start + t_upd[k]
        timeline.append({
            "bucket": k,
            "prep_s": [prep_start, prep_done],
            "link_s": [link_start, link_done],
            "update_s": [upd_start, upd_done],
        })
    serial = sum(t_prep) + sum(t_link) + sum(t_upd)
    return {
        "serial_model_s": serial,
        "overlap_model_s": upd_done,
        "model_speedup": serial / upd_done,
        "link_total_s": sum(t_link),
        "compute_total_s": sum(t_prep) + sum(t_upd),
        "dcn_bytes_per_s": DCN_BYTES_PER_S,
        "hbm_bytes_per_s": HBM_BYTES_PER_S,
        "timeline": timeline,
    }


# modeled backward compute: recompute-forward + backward passes over a
# stage's parameter bytes (the staged backward is remat-style — each
# layer's VJP re-reads its params ~BWD_PASSES times against HBM)
BWD_PASSES = 6.0


def synthetic_layered_tree(num_layers: int, d: int,
                           vocab: int) -> Dict[str, jnp.ndarray]:
    """A uniform-stack-shaped gradient tree: embedding table, stacked
    per-layer matrices, head. Mirrors the layer partition the staged
    backward flushes against (models/transformer.py)."""
    rng = np.random.default_rng(0)

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    return {
        "embed": arr(vocab, d),
        "layers": {"attn": arr(num_layers, d, 3 * d),
                   "mlp_in": arr(num_layers, d, 4 * d),
                   "mlp_out": arr(num_layers, 4 * d, d)},
        "head": arr(d, vocab),
    }


def layered_pieces(tree: Dict[str, jnp.ndarray], num_layers: int):
    """Per-leaf (offset, n, stage) pieces for the synthetic tree — the
    uniform-stack backward partition: head at stage 0, layer l at
    stage L - l, embedding at stage L + 1."""
    L = num_layers
    pieces = []
    stage_bytes = [0.0] * (L + 2)
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        top = path[0].key
        n = int(np.prod(leaf.shape))
        if top == "layers":
            per = n // L
            pieces.append([(l * per, per, L - l) for l in range(L)])
            for l in range(L):
                stage_bytes[L - l] += per * 4
        elif top == "embed":
            pieces.append([(0, n, L + 1)])
            stage_bytes[L + 1] += n * 4
        else:
            pieces.append([(0, n, 0)])
            stage_bytes[0] += n * 4
    return pieces, stage_bytes


def modeled_backward_timeline(layout: bkt.BucketLayout, ranks: int,
                              readiness, stage_bytes, *,
                              compress: bool,
                              block_size: int = _BLOCK
                              ) -> Dict[str, Any]:
    """Bwd+link timeline for the backward-overlap flush schedule.

    The staged backward walks stages 0..S-1 (head, layers back to
    front, embed) at ``BWD_PASSES`` HBM passes over each stage's
    parameter bytes; bucket *k*'s send-side prep can start no earlier
    than ``stage_done[readiness[k]]``, then the standard 3-stage
    prep | link | update pipeline recurrence applies in flush order.
    The after-backward ("buckets") pipeline is the SAME recurrence
    gated on the full backward being done — so the comparison isolates
    exactly the early-flush win: link time hidden under backward
    compute.
    """
    nb = layout.num_buckets
    bucket_f32 = layout.bucket_elems * 4
    t_prep = [(3.0 if compress else 1.0) * bucket_f32 / HBM_BYTES_PER_S
              ] * nb
    t_link = [bkt.modeled_bucket_link_bytes(
        layout, ranks, k, compress=compress, block_size=block_size)
        / DCN_BYTES_PER_S for k in range(nb)]
    t_upd = [7.0 * bucket_f32 / HBM_BYTES_PER_S] * nb

    t_bwd = [BWD_PASSES * b / HBM_BYTES_PER_S for b in stage_bytes]
    stage_done = []
    t = 0.0
    for s in range(len(t_bwd)):
        t += t_bwd[s]
        stage_done.append(t)
    bwd_total = t

    def pipeline(ready_at):
        prep_done = link_done = upd_done = 0.0
        timeline = []
        order = sorted(range(nb), key=lambda k: (readiness[k], k))
        for k in order:
            prep_start = max(prep_done, ready_at(k))
            prep_done = prep_start + t_prep[k]
            link_start = max(link_done, prep_done)
            link_done = link_start + t_link[k]
            upd_start = max(upd_done, link_done)
            upd_done = upd_start + t_upd[k]
            timeline.append({"bucket": k,
                             "ready_s": ready_at(k),
                             "prep_s": [prep_start, prep_done],
                             "link_s": [link_start, link_done],
                             "update_s": [upd_start, upd_done]})
        return upd_done, timeline

    bwd_overlap_total, timeline = pipeline(
        lambda k: stage_done[readiness[k]])
    after_backward_total, _ = pipeline(lambda k: bwd_total)
    return {
        "bwd_total_s": bwd_total,
        "backward_overlap_model_s": max(bwd_overlap_total, bwd_total),
        "after_backward_model_s": after_backward_total,
        "model_speedup_vs_after_backward":
            after_backward_total / max(bwd_overlap_total, bwd_total),
        "link_total_s": sum(t_link),
        "readiness": list(readiness),
        "bwd_passes": BWD_PASSES,
        "timeline": timeline,
    }


def bench_backward(mesh, pods: int, bucket_mb: float, iters: int,
                   compress: bool, *, num_layers: int = 6, d: int = 64,
                   vocab: int = 512) -> Dict[str, Any]:
    """The backward-overlap flush schedule: modeled timeline + a
    flush-ORDER pipeline run on the host mesh asserting the readiness
    order cannot change values (bit-identical to the monolithic
    exchange)."""
    tree = synthetic_layered_tree(num_layers, d, vocab)
    layout = bkt.build_layout(tree, bucket_mb=bucket_mb,
                              multiple_of=pods * _BLOCK)
    pieces, stage_bytes = layered_pieces(tree, num_layers)
    readiness = bkt.bucket_readiness(layout, pieces)
    weights = [1.0, -0.5][:pods]
    stacked = jax.tree.map(
        lambda v: jnp.stack([w * v for w in weights]), tree)
    spec = jax.tree.map(lambda _: NamedSharding(mesh, P("pod")), stacked)
    stacked = jax.device_put(stacked, spec)

    def serial(gl):
        g = jax.tree.map(lambda a: a[0], gl)
        flat = bkt.pack_buckets(g, layout)
        red, _ = bkt.exchange_buckets(
            flat, None, axis="pod", axis_size=pods, compress=compress,
            block_size=_BLOCK, total=layout.total)
        return red

    def flush_ordered(gl):
        g = jax.tree.map(lambda a: a[0], gl)
        flat = bkt.pack_buckets(g, layout)
        x = flat.reshape(layout.num_buckets, pods, -1)
        onehot = compat.manual_axis_onehot("pod", pods, tie=flat)

        def prep(k, raw_k):
            return bkt.prepare_bucket(
                raw_k, None, compress=compress, block_size=_BLOCK,
                key=None, impl="reference", interpret=False)

        def exchange(k, prepared):
            payload, resid1 = prepared
            return bkt.exchange_prepared_bucket(
                payload, resid1, axis="pod", axis_size=pods,
                compress=compress, block_size=_BLOCK, impl="reference",
                interpret=False, onehot=onehot)

        pipe = bkt.BucketFlushPipeline(readiness, prep, exchange)
        for stage in range(num_layers + 2):
            pipe.flush_ready_buckets(stage, lambda k: x[k])
        outs, _, _ = pipe.finish()
        return jnp.stack(outs)

    results: Dict[str, Any] = {}
    outs = {}
    for name, f in (("serial", serial), ("flush_ordered", flush_ordered)):
        sm = compat.shard_map(f, mesh=mesh, in_specs=P("pod"),
                              out_specs=P(), axis_names={"pod"},
                              check_vma=False)
        jf = jax.jit(sm)
        out = jax.block_until_ready(jf(stacked))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jax.block_until_ready(jf(stacked))
        results[name] = {"avg_ms": (time.perf_counter() - t0) / iters
                         * 1e3}
        outs[name] = out
    np.testing.assert_array_equal(np.asarray(outs["serial"]),
                                  np.asarray(outs["flush_ordered"]))
    results["exact_match"] = True
    results["model"] = modeled_backward_timeline(
        layout, pods, readiness, stage_bytes, compress=compress)
    results["_layout"] = {
        "total_bytes": layout.total_bytes,
        "bucket_elems": layout.bucket_elems,
        "num_buckets": layout.num_buckets,
        "num_layers": num_layers,
        "compress": compress,
    }
    return results


def bench_modes(tree: Dict[str, jnp.ndarray], mesh, pods: int,
                bucket_mb: float, iters: int,
                compress: bool) -> Dict[str, Any]:
    layout = bkt.build_layout(tree, bucket_mb=bucket_mb,
                              multiple_of=pods * _BLOCK)
    ocfg = OptimizerConfig(grad_clip=0.0)     # streamable fused update
    dmask = bkt.decay_mask(layout)
    lr = jnp.float32(1e-3)
    step_no = jnp.ones((), jnp.int32)
    weights = [1.0, -0.5][:pods]
    stacked = jax.tree.map(
        lambda v: jnp.stack([w * v for w in weights]), tree)
    spec = jax.tree.map(lambda _: NamedSharding(mesh, P("pod")), stacked)
    stacked = jax.device_put(stacked, spec)
    pb0 = bkt.pack_buckets(tree, layout)      # stand-in packed params
    m0 = jnp.zeros_like(pb0)
    v0 = jnp.zeros_like(pb0)

    def serial(gl, pb, m, v):
        g = jax.tree.map(lambda a: a[0], gl)
        flat = bkt.pack_buckets(g, layout)
        red, _ = bkt.exchange_buckets(
            flat, None, axis="pod", axis_size=pods, compress=compress,
            block_size=_BLOCK, total=layout.total)
        return adam.apply_update_flat(pb, red, m, v, step_no, ocfg, lr,
                                      decay_mask=dmask)

    def overlap(gl, pb, m, v):
        g = jax.tree.map(lambda a: a[0], gl)
        flat = bkt.pack_buckets(g, layout)

        def hook(carry, red_k, xs_k, k):
            p_k, m_k, v_k, dm_k = xs_k
            return carry, adam.apply_update_flat(
                p_k, red_k, m_k, v_k, step_no, ocfg, lr,
                decay_mask=dm_k)

        outs, _, _ = bkt.exchange_buckets_overlapped(
            flat, None, axis="pod", axis_size=pods, compress=compress,
            block_size=_BLOCK, bucket_fn=hook, fn_carry=0.0,
            bucket_xs=(pb, m, v, dmask))
        return outs

    results: Dict[str, Any] = {}
    outs = {}
    for name, f in (("serial", serial), ("overlap", overlap)):
        sm = compat.shard_map(f, mesh=mesh, in_specs=(P("pod"), P(), P(),
                                                      P()),
                              out_specs=(P(), P(), P()),
                              axis_names={"pod"}, check_vma=False)
        jf = jax.jit(sm)
        out = jax.block_until_ready(jf(stacked, pb0, m0, v0))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jax.block_until_ready(jf(stacked, pb0, m0, v0))
        dt = (time.perf_counter() - t0) / iters
        outs[name] = out
        results[name] = {
            "avg_ms": dt * 1e3,
            "collectives": count_pod_collectives(sm, stacked, pb0, m0,
                                                 v0),
        }
    # the fused pipeline must be exactly the monolithic reduce+update
    for a, b in zip(jax.tree.leaves(outs["serial"]),
                    jax.tree.leaves(outs["overlap"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    results["exact_match"] = True
    results["model"] = modeled_timeline(layout, pods, compress=compress)
    results["_layout"] = {
        "total_bytes": layout.total_bytes,
        "bucket_elems": layout.bucket_elems,
        "num_buckets": layout.num_buckets,
        "compress": compress,
    }
    return results


def check_invariants(res: Dict[str, Any]) -> None:
    """Acceptance invariant — fail loudly on regression."""
    for mode in ("backward_fp32", "backward_int8"):
        m = res[mode]["model"]
        assert res[mode]["exact_match"], (
            f"{mode}: flush-ordered pipeline diverged from the "
            f"monolithic exchange")
        assert (m["backward_overlap_model_s"]
                < m["after_backward_model_s"]), (
            f"{mode}: modeled backward-overlap step "
            f"{m['backward_overlap_model_s']:.3e}s not strictly below "
            f"the after-backward pipeline "
            f"{m['after_backward_model_s']:.3e}s")
        # flushing during backprop can never beat the physical floors
        assert m["backward_overlap_model_s"] >= m["bwd_total_s"]
        assert m["backward_overlap_model_s"] >= m["link_total_s"]
    for mode in ("fp32", "int8"):
        nb = res[mode]["_layout"]["num_buckets"]
        assert nb >= 2, (
            f"{mode}: layout collapsed to {nb} bucket(s) — nothing to "
            f"pipeline; lower --bucket-mb so the tree splits into >= 2 "
            f"buckets")
        m = res[mode]["model"]
        assert m["overlap_model_s"] < m["serial_model_s"], (
            f"{mode}: modeled overlapped step {m['overlap_model_s']:.3e}s "
            f"not strictly below serial {m['serial_model_s']:.3e}s")
        assert res[mode]["exact_match"]
        # the pipeline trades launches for overlap: 2 per bucket
        nb = res[mode]["_layout"]["num_buckets"]
        floor = 0 if compat.NATIVE_MANUAL_COLLECTIVES else 1
        assert res[mode]["overlap"]["collectives"] <= 2 * nb + floor, (
            f"{mode}: {res[mode]['overlap']['collectives']} collectives "
            f"exceeds 2/bucket bound {2 * nb + floor}")


def main(quick: bool = False, out: str = "BENCH_overlap.json",
         bucket_mb: float = 0.25) -> Dict[str, Any]:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pods = 2
    if quick:
        tree = synthetic_grad_tree(num_leaves=12, scale=24)
        bucket_mb = min(bucket_mb, 0.002)
        iters = 2
    else:
        tree = synthetic_grad_tree(num_leaves=48, scale=96)
        iters = 8

    bwd_kw = (dict(num_layers=4, d=32, vocab=256) if quick
              else dict(num_layers=8, d=96, vocab=1024))
    res: Dict[str, Any] = {
        "fp32": bench_modes(tree, mesh, pods, bucket_mb, iters,
                            compress=False),
        "int8": bench_modes(tree, mesh, pods, bucket_mb, iters,
                            compress=True),
        "backward_fp32": bench_backward(mesh, pods, bucket_mb, iters,
                                        compress=False, **bwd_kw),
        "backward_int8": bench_backward(mesh, pods, bucket_mb, iters,
                                        compress=True, **bwd_kw),
    }
    check_invariants(res)

    print(f"[overlap_bench] "
          f"{res['fp32']['_layout']['num_buckets']} buckets x "
          f"{res['fp32']['_layout']['bucket_elems']} elems")
    print("| mode | serial model ms | overlap model ms | model speedup |"
          " serial ms | overlap ms |")
    for mode in ("fp32", "int8"):
        m = res[mode]["model"]
        print(f"| {mode} | {m['serial_model_s'] * 1e3:15.3f} | "
              f"{m['overlap_model_s'] * 1e3:16.3f} | "
              f"{m['model_speedup']:13.2f} | "
              f"{res[mode]['serial']['avg_ms']:9.2f} | "
              f"{res[mode]['overlap']['avg_ms']:10.2f} |")
    print("| backward-overlap | bwd ms | after-bwd pipeline ms | "
          "bwd-overlap ms | speedup |")
    for mode in ("backward_fp32", "backward_int8"):
        m = res[mode]["model"]
        print(f"| {mode} | {m['bwd_total_s'] * 1e3:6.3f} | "
              f"{m['after_backward_model_s'] * 1e3:21.3f} | "
              f"{m['backward_overlap_model_s'] * 1e3:14.3f} | "
              f"{m['model_speedup_vs_after_backward']:7.2f} |")
    with open(out, "w") as fh:
        json.dump(res, fh, indent=2)
    print(f"[overlap_bench] wrote {out}; modeled overlapped step "
          f"{res['int8']['model']['model_speedup']:.2f}x faster than "
          f"serial (int8), backward-overlap "
          f"{res['backward_int8']['model']['model_speedup_vs_after_backward']:.2f}x "
          f"faster than the after-backward pipeline (int8), exact fp32 "
          f"match with monolithic: {res['fp32']['exact_match']}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small tree, 2 iters, invariant smoke check")
    ap.add_argument("--out", default="BENCH_overlap.json")
    ap.add_argument("--bucket-mb", type=float, default=0.25)
    args = ap.parse_args()
    main(quick=args.quick, out=args.out, bucket_mb=args.bucket_mb)
