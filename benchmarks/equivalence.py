"""The HetSeq invariant, measured: weighted het-DP gradients vs
single-process gradients over random capacity mixes.

This is the methodological core of the reproduction — the paper's claim
that heterogeneous distributed training "does not sacrifice model
performance" is true *exactly* (not statistically) when aggregation is
weighted correctly. We report the max absolute gradient deviation across
random splits; at fp32 it sits at numerical noise (<1e-5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import capacity, dummy, weighting
from repro.models.model import build_model


def main(trials: int = 8, quiet: bool = False):
    cfg = dataclasses.replace(cfgbase.smoke_config("tinyllama-1.1b"),
                              compute_dtype="float32")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    g, s = 10, 16

    def single(samples):
        batch = {"inputs": jnp.asarray(samples["inputs"]),
                 "labels": jnp.asarray(samples["labels"]),
                 "weights": jnp.ones((g, s))}

        def obj(p, b):
            o, w, _ = m.loss_fn(p, b)
            return o, w
        (o, w), grads = jax.value_and_grad(obj, has_aux=True)(params,
                                                              batch)
        return float(o / w), weighting.scale_grads(grads, w)

    rows = []
    for t in range(trials):
        samples = {
            "inputs": rng.integers(0, cfg.vocab_size, (g, s)).astype(
                np.int32),
            "labels": rng.integers(0, cfg.vocab_size, (g, s)).astype(
                np.int32)}
        loss_ref, g_ref = single(samples)
        n_workers = int(rng.integers(2, 6))
        caps = rng.integers(0, 4, n_workers).astype(float)
        if caps.sum() == 0:
            caps[0] = 1.0
        plan = capacity.plan_capacities(g, caps)
        packed = dummy.pack_global_batch(samples, plan)
        b = plan.buffer_rows
        wbs = [{k: jnp.asarray(packed[k][r * b:(r + 1) * b])
                for k in packed} for r in range(plan.num_ranks)]
        loss_het, g_het = weighting.simulate_workers(m.loss_fn, params,
                                                     wbs)
        gerr = max(float(jnp.max(jnp.abs(a - bb))) for a, bb in
                   zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_het)))
        lerr = abs(loss_ref - float(loss_het))
        rows.append((caps.tolist(), lerr, gerr))
    if not quiet:
        print("\n== HetSeq equivalence invariant ==")
        print(f"| {'capacities':24s} | {'loss err':>10s} | "
              f"{'max grad err':>12s} |")
        for caps, lerr, gerr in rows:
            print(f"| {str(caps):24s} | {lerr:10.2e} | {gerr:12.2e} |")
        worst = max(r[2] for r in rows)
        print(f"   worst-case grad deviation: {worst:.2e} "
              f"({'EXACT (fp noise)' if worst < 1e-4 else 'CHECK'})")
    return rows


if __name__ == "__main__":
    main()
