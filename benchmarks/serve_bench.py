"""Continuous-batching serving benchmark (fail-loud) -> BENCH_serve.json.

Runs the real serving engine (repro/serve: paged KV cache, per-sequence
decode depths, capacity-aware admission) on a tiny fp32 model over an
open-loop mixed-length trace and asserts three invariants, loudly:

(a) **Continuous batching pays.** Modeled tokens/sec of the engine must
    be STRICTLY above a static-batch baseline modeled on the SAME trace
    with the same cost model (one unit == one decode-token on a
    speed-1.0 pod). The baseline is the pre-engine serving loop: FIFO
    batches of ``slots`` requests, wait for the whole batch to arrive,
    pad prefill to the batch-max prompt, decode in lock-step until the
    batch-max generation length, split rows evenly across pods
    (capacity-unaware). The engine admits on arrival, frees slots the
    moment a sequence finishes, and routes min-max active/speed — if it
    cannot beat lock-step padding under mixed-length traffic, the whole
    subsystem is dead weight.

(b) **Bit-identity.** For a single sequence the paged path must be an
    implementation detail: generated token ids from the engine (block
    tables, bucket-padded prefill, ``mode="drop"`` scatter /
    ``mode="fill"`` gather) must equal ``launch/serve.static_generate``
    (contiguous cache, scalar position) exactly, token for token, in
    fp32 with dense attention. Any drift means the block indexing or
    padding masks leak into the math.

(c) **Capacity-aware routing.** Under saturation (arrivals all at t=0,
    2x the slot count) with skewed pod speeds, per-pod peak concurrency
    must equal the CapacityPlan row split — proportional to speed, so a
    slower pod holds strictly fewer concurrent sequences than a faster
    one — and never exceed it.

(d) **Decode-step roofline.** Modeled HBM bytes/token of the paged
    Pallas decode kernels (`attention_impl="pallas"`: KV blocks
    gathered through the block table INSIDE the kernel, one DMA pass,
    scores/probs never leave VMEM) must be STRICTLY below the
    materialize-then-attend model (`"reference"`: gather read + window
    write + attend re-read, plus fp32 score/prob round-trips) at every
    realistic (max_blocks, block_size) point, for both GQA and
    absorbed-MLA head geometries. The measured leg runs the engine on
    the smoke trace with both impls and asserts token-identity — the
    kernel's byte advantage is only claimable if its math is the
    reference's math.

Also records block-pool utilization (mean/peak) and the p50/p99 modeled
time-per-token of the engine run. Quick mode shrinks the trace; the
invariants are identical in both tiers. The emitted JSON is
byte-deterministic given ``seed`` — wall-clock timings are printed,
never written.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.abspath(__file__)), "..", "src"))

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro import compat
from repro.configs import base as cfgbase
from repro.launch import serve as serve_mod
from repro.launch import steps as steps_mod
from repro.models.kvcache import PagedLayout
from repro.models.model import build_model
from repro.serve import Request


def _tiny_model():
    # fp32 + dense attention: bitwise-reproducible reference math
    cfg = dataclasses.replace(
        cfgbase.smoke_config("tinyllama-1.1b"),
        compute_dtype="float32", attention_impl="dense",
        num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=64)
    return cfg, build_model(cfg)


def _layout(slots: int, max_seq: int, block_size: int = 4) -> PagedLayout:
    mbs = -(-max_seq // block_size)
    return PagedLayout(block_size=block_size, num_blocks=slots * mbs,
                       max_blocks_per_seq=mbs)


def _even_split(rows: int, pods: int) -> List[int]:
    base, rem = divmod(rows, pods)
    return [base + (1 if p < rem else 0) for p in range(pods)]


def _gqa_decode_bytes(mb: int, bs: int, hkv: int, q_per_kv: int,
                      dh: int, itemsize: int) -> Dict[str, int]:
    """Modeled HBM bytes to decode ONE token of ONE sequence through
    ONE GQA attention layer, paged KV window of ``mb`` blocks x ``bs``
    tokens.

    kernel (in-kernel gather, flash_decode_paged_pallas): each K/V
    block crosses HBM->VMEM exactly once via the block-table-driven
    DMA; q in, o out; scores/probs live in VMEM scratch only.

    materialize (reference): ``.at[tables].get`` reads the window and
    WRITES a contiguous copy, attend re-reads it, and the dense softmax
    round-trips fp32 scores and probs (write+read each) through HBM.
    """
    h = hkv * q_per_kv
    window = 2 * mb * bs * hkv * dh * itemsize       # K + V blocks
    qo = 2 * h * dh * itemsize                       # q read + out write
    probs = 4 * h * mb * bs * 4                      # scores + probs, wr+rd, fp32
    return {"kernel": window + qo,
            "materialize": 3 * window + qo + probs}


def _mla_decode_bytes(mb: int, bs: int, h: int, r: int, dr: int,
                      itemsize: int) -> Dict[str, int]:
    """Same model for absorbed-MLA decode (latent rank ``r``, rope dim
    ``dr``). The streaming kernel reads each ckv/kr tile once and
    reuses the ckv tile in VMEM for BOTH the score and value matmuls;
    the reference gathers, writes the window, then reads ckv twice
    (score + value) and kr once, with the same fp32 prob round-trips.
    """
    s_g = mb * bs
    ckv, kr = s_g * r * itemsize, s_g * dr * itemsize
    qo = (h * (r + dr) + h * r) * itemsize           # q_abs+q_r in, out
    probs = 4 * h * s_g * 4
    return {"kernel": ckv + kr + qo,
            "materialize": 4 * ckv + 3 * kr + qo + probs}


def _static_baseline(reqs: Sequence[Request], slots: int,
                     speeds: Sequence[float]) -> Dict:
    """Model the pre-engine static-batch loop on the same trace.

    Same cost model as ServeEngine: prefill of an L-padded group costs
    max_p rows_p * L / speed_p, one decode iteration costs
    max_p rows_p / speed_p. FIFO batches of ``slots``; a batch starts
    only when its last member has arrived AND the previous batch
    finished; every row decodes to the batch-max generation length.
    """
    order = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    t, total = 0.0, 0
    batches = 0
    for lo in range(0, len(order), slots):
        batch = order[lo:lo + slots]
        start = max(t, max(r.arrival for r in batch))
        l_max = max(len(r.prompt) for r in batch)
        g_max = max(r.max_new_tokens for r in batch)
        rows = _even_split(len(batch), len(speeds))
        dt_prefill = max(rows[p] * l_max / speeds[p]
                         for p in range(len(speeds)) if rows[p] > 0)
        dt_iter = max(rows[p] / speeds[p]
                      for p in range(len(speeds)) if rows[p] > 0)
        # prefill emits token 1; g_max - 1 lock-step decode iterations
        t = start + dt_prefill + (g_max - 1) * dt_iter
        total += sum(r.max_new_tokens for r in batch)
        batches += 1
    return {"modeled_time": t, "total_tokens": total,
            "modeled_tokens_per_sec": total / t if t > 0 else 0.0,
            "batches": batches}


def _run_engine(model, params, mesh, layout, slots, prefill_batch,
                speeds, reqs):
    with compat.set_mesh(mesh):
        eng = serve_mod.build_engine(model, params, mesh, layout,
                                     slots, prefill_batch, speeds)
        return eng.run(reqs)


def main(quick: bool = False, out: str = "BENCH_serve.json",
         seed: int = 0) -> Dict:
    t_all = time.time()
    cfg, model = _tiny_model()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(seed))
    failures: List[str] = []
    record: Dict = {"quick": quick, "seed": seed,
                    "arch": cfg.name, "compute_dtype": cfg.compute_dtype}

    # -- smoke: 3 mixed-length arrivals end to end ------------------------
    slots = 4
    layout = _layout(slots, max_seq=24)
    smoke_reqs = [Request(rid=0, prompt=(1, 2, 3), max_new_tokens=4,
                          arrival=0.0),
                  Request(rid=1, prompt=tuple(range(1, 12)),
                          max_new_tokens=2, arrival=1.0),
                  Request(rid=2, prompt=(5, 6), max_new_tokens=6,
                          arrival=2.0)]
    res = _run_engine(model, params, mesh, layout, slots, 2,
                      [1.0, 0.5], smoke_reqs)
    short = {r.rid: len(res.tokens[r.rid]) for r in smoke_reqs}
    want = {r.rid: r.max_new_tokens for r in smoke_reqs}
    record["smoke"] = {"tokens_per_request": short,
                       "decode_steps": res.stats["decode_steps"]}
    if short != want:
        failures.append(f"smoke: generated lengths {short} != "
                        f"requested {want}")

    # -- (a) continuous vs static-batch modeled throughput ----------------
    n_req = 12 if quick else 24
    slots = 4
    layout = _layout(slots, max_seq=24 + 16)
    reqs = serve_mod.synthetic_requests(
        n_req, cfg.vocab_size, rate=0.25, prompt_lens=(4, 24),
        gen_lens=(2, 16), seed=seed)
    speeds = [1.0, 0.5]
    res = _run_engine(model, params, mesh, layout, slots, 2, speeds, reqs)
    static = _static_baseline(reqs, slots, speeds)
    cont_tps = res.stats["modeled_tokens_per_sec"]
    ok_tp = cont_tps > static["modeled_tokens_per_sec"]
    record["throughput"] = {
        "requests": n_req, "slots": slots, "pod_speeds": speeds,
        "continuous": {k: res.stats[k] for k in
                       ("modeled_time", "total_tokens",
                        "modeled_tokens_per_sec", "p50_time_per_token",
                        "p99_time_per_token", "mean_ttft",
                        "decode_steps", "prefill_groups",
                        "preemptions")},
        "static": static,
        "speedup": (cont_tps / static["modeled_tokens_per_sec"]
                    if static["modeled_tokens_per_sec"] > 0 else 0.0),
        "strictly_better": ok_tp,
    }
    record["block_util"] = {"mean": res.stats["block_util_mean"],
                            "peak": res.stats["block_util_peak"]}
    if res.stats["total_tokens"] != static["total_tokens"]:
        failures.append(
            f"throughput: engine generated {res.stats['total_tokens']} "
            f"tokens but the trace asks for {static['total_tokens']}")
    if not ok_tp:
        failures.append(
            f"throughput: continuous batching ({cont_tps:.3f} tok/unit) "
            f"is not strictly above the static-batch baseline "
            f"({static['modeled_tokens_per_sec']:.3f} tok/unit)")
    print(f"[serve_bench] throughput: continuous {cont_tps:.3f} vs "
          f"static {static['modeled_tokens_per_sec']:.3f} tok/unit "
          f"({record['throughput']['speedup']:.2f}x), block util "
          f"mean {res.stats['block_util_mean']:.2f} "
          f"peak {res.stats['block_util_peak']:.2f}")

    # -- (b) single-sequence bit-identity vs the static path --------------
    rng = np.random.default_rng(seed + 1)
    plen, gen = 7, 6
    prompt = tuple(int(x) for x in rng.integers(0, cfg.vocab_size, plen))
    layout = _layout(2, max_seq=plen + gen)
    res = _run_engine(model, params, mesh, layout, 2, 1, [1.0],
                      [Request(rid=0, prompt=prompt,
                               max_new_tokens=gen, arrival=0.0)])
    paged_toks = res.tokens[0]
    with compat.set_mesh(mesh):
        ref = serve_mod.static_generate(
            model, params, mesh,
            np.asarray([prompt], np.int32), gen)
    ref_toks = [int(x) for x in ref[0]]
    ok_bit = paged_toks == ref_toks
    record["bit_identity"] = {"prompt_len": plen, "gen": gen,
                              "paged": paged_toks, "static": ref_toks,
                              "identical": ok_bit}
    if not ok_bit:
        failures.append(f"bit_identity: paged {paged_toks} != "
                        f"static {ref_toks}")
    print(f"[serve_bench] bit_identity: paged==static {ok_bit} "
          f"({paged_toks})")

    # -- (c) capacity-aware routing under saturation ----------------------
    speeds = [1.0, 0.5, 0.25]
    slots = 7
    layout = _layout(slots, max_seq=20)
    reqs = serve_mod.synthetic_requests(
        2 * slots, cfg.vocab_size, rate=0.0, prompt_lens=(4, 10),
        gen_lens=(8, 10), seed=seed)
    res = _run_engine(model, params, mesh, layout, slots, 4, speeds, reqs)
    limits = res.stats["pod_limits"]
    peaks = res.stats["peak_active_per_pod"]
    ok_cap = all(pk <= lm for pk, lm in zip(peaks, limits))
    ok_sat = peaks == limits
    # strictly fewer concurrent rows on strictly slower pods
    ok_mono = all(
        limits[p] > limits[q]
        for p in range(len(speeds)) for q in range(len(speeds))
        if speeds[p] > 2 * speeds[q])
    record["routing"] = {"pod_speeds": speeds, "slots": slots,
                         "pod_limits": limits,
                         "peak_active_per_pod": peaks,
                         "within_limits": ok_cap, "saturated": ok_sat,
                         "monotone_in_speed": ok_mono}
    if not ok_cap:
        failures.append(f"routing: peak concurrency {peaks} exceeds "
                        f"capacity limits {limits}")
    if not ok_sat:
        failures.append(f"routing: under 2x-slot saturation peaks "
                        f"{peaks} never reached limits {limits}")
    if not ok_mono:
        failures.append(f"routing: limits {limits} not proportional to "
                        f"pod speeds {speeds}")
    print(f"[serve_bench] routing: speeds {speeds} -> limits {limits}, "
          f"peaks {peaks}")

    # -- (d) decode-step roofline: in-kernel gather vs materialize --------
    # Modeled leg: bytes/token swept at realistic paged-window shapes
    # (bf16 pools; GQA = llama-70B-ish 8 KV heads x 128, MLA =
    # deepseek-ish h=128 r=512 dr=64). The in-kernel-gather model must
    # be STRICTLY below materialize-then-attend at every point.
    sweep = []
    for mb in (4, 16, 64, 256):
        for bs in (16, 32):
            g = _gqa_decode_bytes(mb, bs, hkv=8, q_per_kv=4, dh=128,
                                  itemsize=2)
            m = _mla_decode_bytes(mb, bs, h=128, r=512, dr=64,
                                  itemsize=2)
            row = {"max_blocks": mb, "block_size": bs,
                   "gqa": g, "mla": m,
                   "gqa_ratio": g["materialize"] / g["kernel"],
                   "mla_ratio": m["materialize"] / m["kernel"]}
            sweep.append(row)
            for name, cell in (("gqa", g), ("mla", m)):
                if not cell["kernel"] < cell["materialize"]:
                    failures.append(
                        f"decode_roofline: {name} in-kernel-gather byte "
                        f"model ({cell['kernel']}) not strictly below "
                        f"materialize ({cell['materialize']}) at "
                        f"mb={mb} bs={bs}")
    ok_model = all(row[k]["kernel"] < row[k]["materialize"]
                   for row in sweep for k in ("gqa", "mla"))

    # Measured leg: same smoke trace, reference vs pallas engines (same
    # params — init is impl-independent). Off TPU/GPU the pallas path
    # runs in interpret mode (compat warns loudly), so wall time is
    # printed for eyeballs only; the recorded claim is token-identity.
    slots = 4
    layout = _layout(slots, max_seq=24)
    runs = {}
    for impl in ("reference", "pallas"):
        m_impl = build_model(
            dataclasses.replace(cfg, attention_impl=impl))
        t0 = time.time()
        runs[impl] = _run_engine(m_impl, params, mesh, layout, slots, 2,
                                 [1.0, 0.5], smoke_reqs)
        print(f"[serve_bench] roofline measured: {impl} smoke run "
              f"{time.time() - t0:.1f}s wall "
              f"({runs[impl].stats['decode_steps']} decode steps)")
    ok_tok = runs["pallas"].tokens == runs["reference"].tokens
    best = max(sweep, key=lambda r: r["gqa_ratio"])
    record["decode_roofline"] = {
        "itemsize": 2,
        "gqa_heads": {"hkv": 8, "q_per_kv": 4, "dh": 128},
        "mla_heads": {"h": 128, "r": 512, "dr": 64},
        "sweep": sweep,
        "kernel_strictly_better": ok_model,
        "measured": {
            "impls": sorted(runs),
            "decode_steps": {k: v.stats["decode_steps"]
                             for k, v in runs.items()},
            "token_identical": ok_tok,
        },
    }
    if not ok_tok:
        failures.append(
            f"decode_roofline: pallas engine tokens "
            f"{runs['pallas'].tokens} != reference "
            f"{runs['reference'].tokens}")
    print(f"[serve_bench] decode_roofline: modeled kernel<materialize "
          f"{ok_model} (best gqa ratio {best['gqa_ratio']:.2f}x at "
          f"mb={best['max_blocks']} bs={best['block_size']}), measured "
          f"pallas==reference tokens {ok_tok}")

    # wall time is printed, not recorded: the artifact must be
    # byte-deterministic given the seed
    with open(out, "w") as fh:
        json.dump(record, fh, indent=1,
                  default=lambda o: o.item()
                  if isinstance(o, np.generic) else str(o))
    print(f"[serve_bench] wrote {out} ({time.time() - t_all:.1f}s)")
    if failures:
        for f in failures:
            print(f"[serve_bench] INVARIANT BROKEN: {f}")
        raise SystemExit("[serve_bench] fail-loud: "
                         f"{len(failures)} invariant(s) broken")
    return record


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
