"""Paper Table 3, MNIST block: the small-model negative result.

The paper's point: with a tiny model + tiny dataset, DP adds collective
and dispatch overhead without useful parallel work — speedup saturates
near 1x (their 8-node MNIST run was barely faster than 1 node). We
reproduce that shape with a ~100k-param model and a small step count:
expansion should collapse well below 1/nodes.
"""
from __future__ import annotations

import dataclasses

from repro.configs import base as cfgbase
from benchmarks.common import HEADER, grid_configs, run_training


def model_cfg():
    return dataclasses.replace(
        cfgbase.smoke_config("xlstm-125m"),
        num_layers=2, d_model=32, vocab_size=64)


def main(max_nodes: int = 8, steps: int = 10, quiet: bool = False):
    cfg = model_cfg()
    results = []
    for name, nodes, caps in grid_configs(max_nodes):
        r = run_training(name, cfg, data_parallel=nodes,
                         capacities=caps, global_batch=8, seq_len=16,
                         steps=steps)
        results.append(r)
    if not quiet:
        print("\n== Small-model scaling (paper's MNIST negative result) ==")
        print(HEADER)
        base = results[0]
        for r in results:
            print(r.row(base))
        print("   (expansion << 1 expected: DP does not help tiny models)")
    return results


if __name__ == "__main__":
    main()
