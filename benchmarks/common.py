"""Shared benchmark harness: timed heterogeneous training runs.

Mirrors the paper's experimental setup on host devices: each "node" is a
DP rank; heterogeneous configs assign unequal capacities (the paper's
GPU mixes); homogeneous configs assign equal ones. We measure avg step
time, total training time, expansion (efficiency) and speedup — the
columns of paper Table 3.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.configs import base as cfgbase
from repro.configs.base import (HetConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import capacity as cap
from repro.core.dummy import pack_global_batch
from repro.data.synthetic import make_lm_records
from repro.launch import steps as steps_mod
from repro.launch.sharding import batch_specs, named
from repro.models.model import build_model


@dataclasses.dataclass
class BenchResult:
    name: str
    nodes: int
    het: bool
    steps: int
    avg_step_s: float
    total_s: float
    final_loss: float
    first_loss: float

    def row(self, base: Optional["BenchResult"] = None) -> str:
        speedup = base.total_s / self.total_s if base else 1.0
        expansion = speedup / self.nodes if base else 1.0
        return (f"| {self.name:14s} | {self.nodes:5d} | "
                f"{'het' if self.het else 'hom':3s} | {self.steps:5d} | "
                f"{self.avg_step_s * 1e3:10.1f} | {self.total_s:8.2f} | "
                f"{self.final_loss:9.4f} | {expansion:9.2f} | "
                f"{speedup:7.2f} |")


HEADER = (f"| {'config':14s} | nodes | h/h | steps | avg step ms |"
          f"  total s | fin. loss | expansion | speedup |")


def run_training(
    name: str,
    cfg,
    *,
    data_parallel: int,
    capacities: Sequence[float],
    global_batch: int,
    seq_len: int,
    steps: int,
    seed: int = 0,
    lr: float = 3e-3,
    label_smoothing: float = 0.0,
    mask_lm: bool = False,
) -> BenchResult:
    """One timed run. ``data_parallel`` host devices form the DP mesh."""
    model = build_model(cfg)
    mesh = jax.make_mesh((data_parallel, 1), ("data", "model"))
    shape = ShapeConfig("bench", seq_len, global_batch, "train")
    tcfg = TrainConfig(model=cfg, shape=shape, het=HetConfig(),
                       optimizer=OptimizerConfig(
                           lr=lr, warmup_steps=max(steps // 10, 2),
                           schedule="inverse_sqrt",
                           betas=(0.9, 0.98), eps=1e-9))

    plan = cap.plan_capacities(global_batch, capacities)
    rec = make_lm_records(4 * global_batch, seq_len + 1, cfg.vocab_size,
                          seed=seed)
    rng = np.random.default_rng(seed)

    with compat.set_mesh(mesh):
        state = steps_mod.init_train_state(model, tcfg, mesh,
                                           jax.random.PRNGKey(seed))
        step_fn = steps_mod.build_train_step(model, tcfg, mesh)
        bspecs = named(mesh, batch_specs(cfg, mesh, plan.padded_rows))

        def make_batch(i):
            lo = (i * global_batch) % (3 * global_batch)
            samples = {"inputs": rec["inputs"][lo:lo + global_batch,
                                               :seq_len],
                       "labels": rec["labels"][lo:lo + global_batch,
                                               :seq_len]}
            tw = None
            if mask_lm:
                # BERT-style: only masked positions carry loss weight
                tw = (rng.random((global_batch, seq_len)) < 0.15
                      ).astype(np.float32)
                tw[:, 0] = 1.0               # never all-zero
            packed = pack_global_batch(samples, plan, token_weights=tw)
            return jax.device_put(
                {k: jnp.asarray(v) for k, v in packed.items()}, bspecs)

        # warmup (compile)
        state, m0 = step_fn(state, make_batch(0))
        first_loss = float(m0["loss"])
        t0 = time.time()
        last = first_loss
        for i in range(1, steps + 1):
            state, met = step_fn(state, make_batch(i))
            last = met["loss"]
        last = float(last)
        total = time.time() - t0
    return BenchResult(name=name, nodes=data_parallel,
                       het=len(set(capacities)) > 1, steps=steps,
                       avg_step_s=total / steps, total_s=total,
                       final_loss=last, first_loss=first_loss)


def grid_configs(max_nodes: int) -> List[Tuple[str, int, List[float]]]:
    """The paper's 1 / 2(hom) / 2(het) / 4(hom) / 4(het) / 8(het) grid."""
    grid = [("1 node", 1, [1.0])]
    if max_nodes >= 2:
        grid += [("2 (hom)", 2, [1.0, 1.0]),
                 ("2 (het)", 2, [1.5, 0.5])]
    if max_nodes >= 4:
        grid += [("4 (hom)", 4, [1.0] * 4),
                 ("4 (het)", 4, [1.5, 1.5, 0.5, 0.5])]
    if max_nodes >= 8:
        grid += [("8 (het)", 8, [2.0, 1.5, 1.5, 1.0, 1.0, 0.5, 0.5, 0.0])]
    return grid
