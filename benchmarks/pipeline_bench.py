"""Pipeline benchmark: capacity-sized stages vs uniform stages vs DP.

Exercises the ``HetConfig.pipeline_stages`` stack end to end on the
8-host-device mesh and the host-side timeline model
(core/pipeline.py), with three fail-loud acceptance invariants:

  exactness    fp32 / grad_clip=0 / allreduce / scan_layers=False:
               the stages=2 1F1B step (per-stage VJP segments, one
               deterministic microbatch program order) must be
               BIT-IDENTICAL — losses AND params — to the pure-DP
               (stages=1) step over the same global batch. Pipelining
               is a schedule, not a numeric.
  modeled      on a 2:1 pod-speed skew (speeds (2, 1), L=12 layers,
               S=2 stages, M=8 microbatches, DCN 12.5 GB/s, 0.5 GB of
               gradient per layer), the capacity-sized stage cut
               ([8, 4] layers — fast pod holds more depth) must give a
               strictly smaller modeled 1F1B makespan than BOTH the
               uniform cut ([6, 6], the bubble the skew inflates) and
               pure capacity-planned DP (which pays the full-gradient
               DCN sync pipelining avoids). 1F1B must also not lose to
               GPipe on the same cut.
  restore      a checkpoint saved under one stage plan (capacities
               (3, 1) -> layer cut [3, 1]) must restore into a
               DIFFERENT stage plan (uniform [2, 2]) and continue
               BIT-IDENTICALLY to an uninterrupted run — params are
               stored per-leaf, so the stage partition is placement
               metadata, not state (steps.checkpoint_format records it
               via core/pipeline.py stage_record for the restore-time
               log + validation only).

The CPU host mesh runs stages sequentially, so no wall-clock speedup
is claimed from the measured leg; the skew argument lives in the
modeled timeline, same convention as overlap_bench. Emits
``BENCH_pipeline.json`` (``--out`` to relocate).
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import tempfile
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base
from repro.configs.base import (HetConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.core import capacity, dummy
from repro.core import pipeline as pipe
from repro.data import synthetic
from repro.launch import steps
from repro.launch.sharding import named
from repro.models.model import build_model

# the modeled-skew scenario (ISSUE 8 acceptance constants)
MODEL_L = 12                 # layers in the modeled stack
MODEL_S = 2                  # pipeline stages
MODEL_M = 8                  # microbatches in flight
MODEL_SPEEDS = (2.0, 1.0)    # 2:1 pod skew
MODEL_MB_ROWS = 4
MODEL_ROW_LAYER_S = 2e-3     # per-row per-layer fwd compute at speed 1
MODEL_ACT_BYTES = 5e7        # stage-boundary activation per microbatch
MODEL_DCN_BPS = 12.5e9       # 100 Gb/s DCN
MODEL_PARAM_BYTES_LAYER = 0.5e9


def _measured_leg(num_steps: int) -> Dict[str, Any]:
    """stages=2 vs pure DP on the host mesh: bit-exactness + wall."""
    cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                              compute_dtype="float32",
                              scan_layers=False)
    model = build_model(cfg)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeConfig("t", 16, 8, "train")
    rec = synthetic.make_lm_records(16, 17, cfg.vocab_size, seed=5)
    plan = capacity.plan_capacities(16, [1, 1, 1, 1])
    packed = dummy.pack_global_batch(
        {"inputs": rec["inputs"][:, :16],
         "labels": rec["labels"][:, :16]}, plan)
    batch = {k: jnp.asarray(v) for k, v in packed.items()}

    def run(stages):
        tcfg = TrainConfig(
            model=cfg, shape=shape,
            het=HetConfig(grad_reduction="allreduce", accum_steps=4,
                          pipeline_stages=stages),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                      grad_clip=0.0))
        with compat.set_mesh(mesh):
            state = steps.init_train_state(model, tcfg, mesh,
                                           jax.random.PRNGKey(0))
            step = steps.build_train_step(model, tcfg, mesh)
            losses, t0 = [], None
            for i in range(num_steps):
                state, met = step(state, batch)
                losses.append(float(met["loss"]))
                if i == 0:            # first step pays compilation
                    t0 = time.time()
            wall = (time.time() - t0) / max(num_steps - 1, 1)
        return losses, jax.device_get(state), wall

    dp_losses, dp_state, dp_wall = run(1)
    pp_losses, pp_state, pp_wall = run(2)
    if dp_losses != pp_losses:
        raise SystemExit(
            f"pipeline_bench: stages=2 losses diverged from pure DP "
            f"(fp32/clip=0 must be bit-identical): {dp_losses} vs "
            f"{pp_losses}")
    for a, b in zip(jax.tree.leaves(dp_state.params),
                    jax.tree.leaves(pp_state.params)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise SystemExit(
                "pipeline_bench: stages=2 params diverged bitwise "
                "from pure DP after the bit-identical loss trajectory "
                "— the per-stage VJP/accumulation order regressed")
    return {
        "losses": dp_losses,
        "exact_match": True,
        "dp_avg_ms": dp_wall * 1e3,
        "pipeline_avg_ms": pp_wall * 1e3,
    }


def _modeled_leg() -> Dict[str, Any]:
    """The 2:1-skew stage-sizing argument, checked loudly."""
    cap_plan = pipe.plan_stages(MODEL_L, MODEL_SPEEDS)
    uni_plan = pipe.uniform_stages(MODEL_L, MODEL_S)
    kw = dict(num_microbatches=MODEL_M, mb_rows=MODEL_MB_ROWS,
              row_layer_time=MODEL_ROW_LAYER_S,
              act_bytes_per_mb=MODEL_ACT_BYTES,
              dcn_bytes_per_s=MODEL_DCN_BPS)
    t_cap = pipe.modeled_pipeline_step_time(cap_plan, MODEL_SPEEDS, **kw)
    t_uni = pipe.modeled_pipeline_step_time(uni_plan, MODEL_SPEEDS, **kw)
    t_gpipe = pipe.modeled_pipeline_step_time(cap_plan, MODEL_SPEEDS,
                                              schedule="gpipe", **kw)
    t_dp = pipe.modeled_dp_step_time(
        MODEL_L, MODEL_SPEEDS,
        global_rows=MODEL_M * MODEL_MB_ROWS,
        row_layer_time=MODEL_ROW_LAYER_S,
        param_bytes_per_layer=MODEL_PARAM_BYTES_LAYER,
        dcn_bytes_per_s=MODEL_DCN_BPS)
    if not (t_cap < t_uni):
        raise SystemExit(
            f"pipeline_bench: capacity-sized stages "
            f"({cap_plan.layers_per_stage.tolist()}) modeled at "
            f"{t_cap:.4f}s do not beat uniform stages "
            f"({uni_plan.layers_per_stage.tolist()}) at {t_uni:.4f}s "
            f"on the 2:1 skew — stage sizing regressed")
    if not (t_cap < t_dp):
        raise SystemExit(
            f"pipeline_bench: capacity-sized pipeline modeled at "
            f"{t_cap:.4f}s does not beat pure capacity-planned DP at "
            f"{t_dp:.4f}s — the full-gradient sync term vanished from "
            f"the DP model or boundary traffic exploded")
    if not (t_cap <= t_gpipe):
        raise SystemExit(
            f"pipeline_bench: 1F1B ({t_cap:.4f}s) modeled slower than "
            f"GPipe ({t_gpipe:.4f}s) on the same cut")
    return {
        "layers_capacity": cap_plan.layers_per_stage.tolist(),
        "layers_uniform": uni_plan.layers_per_stage.tolist(),
        "capacity_s": t_cap,
        "uniform_s": t_uni,
        "gpipe_s": t_gpipe,
        "dp_s": t_dp,
        "speedup_vs_uniform": t_uni / t_cap,
        "speedup_vs_dp": t_dp / t_cap,
    }


def _restore_leg() -> Dict[str, Any]:
    """Save under stage cut [3,1]; restore into [2,2]; bit-identical."""
    cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                              compute_dtype="float32",
                              scan_layers=False, num_layers=4)
    model = build_model(cfg)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeConfig("t", 16, 8, "train")
    rec = synthetic.make_lm_records(16, 17, cfg.vocab_size, seed=7)
    plan = capacity.plan_capacities(16, [1, 1, 1, 1])
    packed = dummy.pack_global_batch(
        {"inputs": rec["inputs"][:, :16],
         "labels": rec["labels"][:, :16]}, plan)
    batch = {k: jnp.asarray(v) for k, v in packed.items()}

    def tcfg_for(caps):
        return TrainConfig(
            model=cfg, shape=shape,
            het=HetConfig(grad_reduction="allreduce", accum_steps=4,
                          pipeline_stages=2, capacities=caps),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                      grad_clip=0.0))

    t_skew, t_uni = tcfg_for((3.0, 1.0)), tcfg_for(())
    cut_skew = steps.stage_plan_for(model, t_skew).layers_per_stage
    cut_uni = steps.stage_plan_for(model, t_uni).layers_per_stage
    assert cut_skew.tolist() != cut_uni.tolist(), (cut_skew, cut_uni)

    # uninterrupted reference: 2 steps under the uniform cut
    with compat.set_mesh(mesh):
        st = steps.init_train_state(model, t_uni, mesh,
                                    jax.random.PRNGKey(0))
        f_uni = steps.build_train_step(model, t_uni, mesh)
        st, m1 = f_uni(st, batch)
        st, m2 = f_uni(st, batch)
    ref = jax.device_get(st)
    ref_loss2 = float(m2["loss"])

    # interrupted: 1 step under the SKEWED cut, save, restore into the
    # uniform cut, continue
    with compat.set_mesh(mesh):
        st = steps.init_train_state(model, t_skew, mesh,
                                    jax.random.PRNGKey(0))
        f_skew = steps.build_train_step(model, t_skew, mesh)
        st, m1b = f_skew(st, batch)
    if float(m1b["loss"]) != float(m1["loss"]):
        raise SystemExit(
            "pipeline_bench: step-1 loss differs between stage cuts "
            "— the pipeline schedule changed the numerics")
    host1 = jax.device_get(st)
    ckdir = tempfile.mkdtemp(prefix="pipeline_bench_ck_")
    mgr = CheckpointManager(ckdir)
    fmt_skew = steps.checkpoint_format(model, t_skew, mesh)
    assert fmt_skew["pipeline"]["plan"]["rows_per_rank"] == \
        cut_skew.tolist()
    mgr.save(1, host1, meta={"plan": plan, "format": fmt_skew},
             block=True)

    host, meta = mgr.restore(steps.state_shapes(model, t_uni, mesh))
    saved_cut = meta["format"]["pipeline"]["plan"]["rows_per_rank"]
    with compat.set_mesh(mesh):
        sr = jax.device_put(
            host, named(mesh, steps.state_specs(model, t_uni, mesh)))
        sr, m2b = f_uni(sr, batch)
    got = jax.device_get(sr)
    if float(m2b["loss"]) != ref_loss2:
        raise SystemExit(
            f"pipeline_bench: post-restore loss {float(m2b['loss'])!r} "
            f"!= uninterrupted {ref_loss2!r} across the stage-plan "
            f"change {saved_cut} -> {cut_uni.tolist()}")
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise SystemExit(
                "pipeline_bench: params diverged bitwise after the "
                f"cross-stage-plan restore {saved_cut} -> "
                f"{cut_uni.tolist()}")
    return {
        "saved_cut": saved_cut,
        "restored_cut": cut_uni.tolist(),
        "bit_identical": True,
    }


def main(quick: bool = False,
         out: str = "BENCH_pipeline.json") -> Dict[str, Any]:
    res: Dict[str, Any] = {
        "exactness": _measured_leg(num_steps=2 if quick else 4),
        "modeled": _modeled_leg(),
        "restore": _restore_leg(),
    }
    mo = res["modeled"]
    print(f"| cut | modeled step s |")
    print(f"| capacity {mo['layers_capacity']} | {mo['capacity_s']:.4f} |")
    print(f"| uniform {mo['layers_uniform']} | {mo['uniform_s']:.4f} |")
    print(f"| gpipe-on-capacity | {mo['gpipe_s']:.4f} |")
    print(f"| pure DP | {mo['dp_s']:.4f} |")
    with open(out, "w") as fh:
        json.dump(res, fh, indent=2)
    print(f"[pipeline_bench] wrote {out}; stages=2 bit-identical to "
          f"DP: {res['exactness']['exact_match']}; capacity cut "
          f"{mo['speedup_vs_uniform']:.2f}x vs uniform, "
          f"{mo['speedup_vs_dp']:.2f}x vs pure DP on 2:1 skew; "
          f"cross-stage-plan restore bit-identical: "
          f"{res['restore']['bit_identical']}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer measured steps, same invariants")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
