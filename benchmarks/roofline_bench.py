"""Emit the §Roofline table from dry-run artifacts (artifacts/dryrun).

Reads every <cell>.json the dry-run produced, computes the three-term
roofline (TPU v5e constants) and prints the markdown table used in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from repro.roofline.report import RooflineRow, format_table

_ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts")
# prefer the final consistent grid when present
DEFAULT_DIR = (os.path.join(_ART, "dryrun_final")
               if os.path.isdir(os.path.join(_ART, "dryrun_final"))
               else os.path.join(_ART, "dryrun"))


def load_rows(art_dir: str = DEFAULT_DIR) -> List[RooflineRow]:
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        m = json.load(open(f))
        if m.get("status") != "ok":
            continue
        rows.append(RooflineRow(
            arch=m["arch"], shape=m["shape"], mesh=m["mesh"],
            chips=m["chips"], kind=m["kind"],
            hlo_flops=m["cost"]["hlo_flops"],
            hlo_bytes=m["cost"]["hlo_bytes"],
            ici_bytes=m["collectives"]["ici_bytes"],
            dcn_bytes=m["collectives"]["dcn_bytes"],
            model_flops=m["model_flops"]))
    return rows


def main(art_dir: str = DEFAULT_DIR, quiet: bool = False):
    rows = load_rows(art_dir)
    if not rows:
        print(f"[roofline] no artifacts in {art_dir}; run "
              f"`python -m repro.launch.dryrun` first")
        return []
    if not quiet:
        print("\n== Roofline (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, "
              "~200 GB/s ICI, 25 GB/s DCN per chip) ==")
        print(format_table(sorted(
            rows, key=lambda r: (r.arch, r.shape, r.mesh))))
        doms = {}
        for r in rows:
            doms[r.dominant] = doms.get(r.dominant, 0) + 1
        print(f"   dominant terms: {doms}")
    return rows


if __name__ == "__main__":
    main()
