"""Paper Table 3, Translation block: transformer scaling, hom vs het.

The paper trains transformer-base on WMT14 En-De with label-smoothed CE
(eps=0.1, Adam beta2=0.98) over 1/2/4/8 nodes. We reproduce the
*scalability shape* with a same-family decoder (tinyllama-smoke scaled
up a notch) on synthetic bigram text: step time grows sub-linearly with
node count while per-epoch work divides, heterogeneous mixes track
homogeneous ones, and the final loss is preserved across configs.
"""
from __future__ import annotations

import dataclasses

from repro.configs import base as cfgbase
from benchmarks.common import HEADER, grid_configs, run_training


def model_cfg():
    return dataclasses.replace(
        cfgbase.smoke_config("tinyllama-1.1b"),
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=4,
        d_ff=352, vocab_size=512)


def main(max_nodes: int = 8, steps: int = 12, global_batch: int = 16,
         seq_len: int = 64, quiet: bool = False):
    cfg = model_cfg()
    results = []
    for name, nodes, caps in grid_configs(max_nodes):
        # paper protocol: constant global epochs => steps per node config
        # shrink as nodes grow; we keep measured steps equal and report
        # per-step time (expansion computes the same either way)
        r = run_training(name, cfg, data_parallel=nodes,
                         capacities=caps, global_batch=global_batch,
                         seq_len=seq_len, steps=steps,
                         label_smoothing=0.1)
        results.append(r)
    if not quiet:
        print("\n== Translation-block scaling (paper Table 3 analogue) ==")
        print(HEADER)
        base = results[0]
        for r in results:
            print(r.row(base))
        hom = {r.nodes: r for r in results if not r.het}
        het = {r.nodes: r for r in results if r.het}
        for n in sorted(set(hom) & set(het)):
            d = abs(hom[n].final_loss - het[n].final_loss)
            print(f"   loss parity @ {n} nodes: |hom-het| = {d:.4f}")
    return results


if __name__ == "__main__":
    main()
