"""Paper Table 3, BERT block: masked-LM scaling, hom vs het.

The paper trains BERT-base (masked-word prediction) with Adam
beta2=0.999 and linear decay over 1-8 nodes. Here the masked-LM
objective is expressed through the HetSeq token-weight mechanism itself:
only masked positions carry loss weight — per-worker weights then differ
organically, exercising the weighted aggregation harder than uniform LM.
"""
from __future__ import annotations

import dataclasses

from repro.configs import base as cfgbase
from benchmarks.common import HEADER, grid_configs, run_training


def model_cfg():
    return dataclasses.replace(
        cfgbase.smoke_config("olmo-1b"),
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8,
        d_ff=352, vocab_size=512)


def main(max_nodes: int = 8, steps: int = 12, global_batch: int = 16,
         seq_len: int = 64, quiet: bool = False):
    cfg = model_cfg()
    results = []
    for name, nodes, caps in grid_configs(max_nodes):
        r = run_training(name, cfg, data_parallel=nodes,
                         capacities=caps, global_batch=global_batch,
                         seq_len=seq_len, steps=steps, mask_lm=True)
        results.append(r)
    if not quiet:
        print("\n== BERT-block scaling (masked-LM via token weights) ==")
        print(HEADER)
        base = results[0]
        for r in results:
            print(r.row(base))
    return results


if __name__ == "__main__":
    main()
