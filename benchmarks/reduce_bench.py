"""Reduction benchmark: per-leaf vs bucketed cross-pod gradient exchange.

Measures, on the 8-host-device mesh (2 pods x 2 data x 2 model), the
four cross-pod reduction schedules wired behind ``HetConfig``:

  per_leaf        — legacy: one psum per pytree leaf
  per_leaf_int8   — legacy: one quantize + full-payload gathers per leaf
  bucketed        — flat-buffer engine: ONE psum_scatter + ONE gather
  bucketed_int8   — flat-buffer engine: ONE fused quantize + ONE payload
                    all_to_all + fused dequant-accum + ONE gather

For each path it reports:
  * cross-pod collective-launch count, counted from the jaxpr (the
    latency-bound quantity a heterogeneous DCN link cares about);
  * modeled per-rank DCN bytes for the *native* schedule
    (core/buckets.py byte models — the CPU psum emulation in compat.py
    moves more bytes but launches the same collectives);
  * measured wall time per reduction on the host mesh;
  * max abs error vs the exact sum.

Acceptance invariant (checked loudly in ``--quick`` mode and on every
full run): the bucketed paths must issue at most
``ceil(total_param_bytes / bucket_bytes)`` = num_buckets cross-pod
collectives per step, and strictly fewer than the per-leaf paths.

Emits ``BENCH_reduce.json`` (``--out`` to relocate).
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import buckets as bkt
from repro.launch import steps as steps_mod

_BLOCK = steps_mod._BLOCK
_COLLECTIVES = ("psum", "all_gather", "all_to_all", "reduce_scatter",
                "all_reduce", "ppermute")


def count_pod_collectives(fn, *args) -> int:
    """Count cross-pod collective eqns in the traced jaxpr of ``fn``."""
    closed = jax.make_jaxpr(fn)(*args)

    def mentions_pod(params) -> bool:
        for key in ("axes", "axis_name", "axis_index_groups"):
            v = params.get(key)
            if v is None:
                continue
            names = v if isinstance(v, (tuple, list)) else (v,)
            if any(n == "pod" for n in names):
                return True
        return False

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _COLLECTIVES and \
                    mentions_pod(eqn.params):
                n += 1
            for v in eqn.params.values():
                for j in jax.tree.leaves(
                        v, is_leaf=lambda x: hasattr(x, "eqns")):
                    if hasattr(j, "eqns"):
                        n += walk(j)
                if hasattr(v, "jaxpr"):           # ClosedJaxpr
                    n += walk(v.jaxpr)
        return n

    return walk(closed.jaxpr)


def synthetic_grad_tree(num_leaves: int, scale: int,
                        seed: int = 0) -> Dict[str, jnp.ndarray]:
    """A transformer-shaped pytree: many mixed-size 1D/2D leaves."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(num_leaves):
        if i % 4 == 0:
            shape: Tuple[int, ...] = (scale + i,)              # biases/norms
        elif i % 4 == 1:
            shape = (scale, scale)                             # square proj
        elif i % 4 == 2:
            shape = (scale, 2 * scale + 1)                     # odd ffn
        else:
            shape = (3, scale, scale // 2)                     # stacked qkv
        tree[f"leaf_{i:02d}"] = jnp.asarray(
            rng.standard_normal(shape).astype(np.float32))
    return tree


def bench_paths(tree: Dict[str, jnp.ndarray], mesh, pods: int,
                bucket_mb: float, iters: int) -> Dict[str, Any]:
    layout = bkt.build_layout(tree, bucket_mb=bucket_mb,
                              multiple_of=pods * _BLOCK)
    # per-pod contributions: pod p holds tree * weight_p
    weights = [1.0, -0.5, 0.25, 2.0][:pods]
    stacked = jax.tree.map(
        lambda v: jnp.stack([w * v for w in weights]), tree)
    ref = jax.tree.map(lambda v: sum(w * np.asarray(v) for w in weights),
                       tree)
    spec = jax.tree.map(lambda _: NamedSharding(mesh, P("pod")), stacked)
    stacked = jax.device_put(stacked, spec)

    def per_leaf(compress):
        def f(gl):
            g = jax.tree.map(lambda a: a[0], gl)
            out, _ = steps_mod._cross_pod_reduce(g, (), compress, pods)
            return out
        return f

    def bucketed(compress):
        def f(gl):
            g = jax.tree.map(lambda a: a[0], gl)
            flat = bkt.pack_buckets(g, layout)
            red, _ = bkt.exchange_buckets(
                flat, None, axis="pod", axis_size=pods,
                compress=compress, block_size=_BLOCK,
                total=layout.total)
            return bkt.unpack_buckets(red, layout)
        return f

    paths = {
        "per_leaf": (per_leaf("none"), False, False),
        "per_leaf_int8": (per_leaf("int8"), True, False),
        "bucketed": (bucketed(False), False, True),
        "bucketed_int8": (bucketed(True), True, True),
    }

    results = {}
    for name, (f, compress, is_bucketed) in paths.items():
        sm = compat.shard_map(f, mesh=mesh, in_specs=P("pod"),
                              out_specs=P(), axis_names={"pod"},
                              check_vma=False)
        jf = jax.jit(sm)
        out = jax.block_until_ready(jf(stacked))       # compile + warmup
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jax.block_until_ready(jf(stacked))
        dt = (time.perf_counter() - t0) / iters
        err = max(
            float(np.max(np.abs(np.asarray(a, np.float32) - b)))
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)))
        if is_bucketed:
            dcn = bkt.modeled_link_bytes(layout, pods, compress=compress,
                                         block_size=_BLOCK)
        else:
            dcn = bkt.modeled_per_leaf_bytes(tree, pods, compress=compress,
                                             block_size=_BLOCK)
        results[name] = {
            "collectives": count_pod_collectives(sm, stacked),
            "modeled_dcn_bytes_per_rank": dcn,
            "avg_ms": dt * 1e3,
            "max_abs_err": err,
        }
    results["_layout"] = {
        "leaves": len(jax.tree.leaves(tree)),
        "total_elems": layout.total,
        "total_bytes": layout.total_bytes,
        "bucket_mb": bucket_mb,
        "bucket_elems": layout.bucket_elems,
        "num_buckets": layout.num_buckets,
        "collective_bound": layout.num_buckets,
        # the native schedule is 2 launches/step for the whole tree; the
        # counted numbers on old-jax stacks include the psum emulation's
        # rank-derivation scatter (compat.py)
        "native_bucketed_collectives": 2,
        "native_manual_collectives": compat.NATIVE_MANUAL_COLLECTIVES,
    }
    return results


def check_invariants(res: Dict[str, Any]) -> None:
    """The acceptance invariant — fail loudly on regression."""
    # the schedule has an inherent floor independent of bucket count:
    # 2 launches natively (exchange + broadcast legs), +1 on the
    # old-jax emulation (rank-derivation scatter, compat.py); a layout
    # with fewer buckets than the floor cannot go below it
    floor = 2 if compat.NATIVE_MANUAL_COLLECTIVES else 3
    bound = max(res["_layout"]["collective_bound"], floor)
    for name in ("bucketed", "bucketed_int8"):
        c = res[name]["collectives"]
        assert c <= bound, (
            f"{name}: {c} cross-pod collectives exceeds "
            f"max(ceil(total_bytes/bucket_bytes), schedule floor)="
            f"{bound}")
    for b, pl in (("bucketed", "per_leaf"),
                  ("bucketed_int8", "per_leaf_int8")):
        assert res[b]["collectives"] < res[pl]["collectives"], (
            f"{b} ({res[b]['collectives']}) not fewer launches than "
            f"{pl} ({res[pl]['collectives']})")
    # exact paths must agree to fp tolerance; int8 to quantization tol
    assert res["bucketed"]["max_abs_err"] <= 1e-5
    assert res["per_leaf"]["max_abs_err"] <= 1e-5
    # the bucketed int8 wire payload counts DATA blocks only (the
    # all-padding tail blocks are never transmitted): packing whole
    # streams can never model MORE DCN bytes than quantizing leaf by
    # leaf, which pads every leaf up to a block boundary
    assert (res["bucketed_int8"]["modeled_dcn_bytes_per_rank"]
            <= res["per_leaf_int8"]["modeled_dcn_bytes_per_rank"]), (
        "bucketed int8 models more DCN bytes than per-leaf int8 "
        f"({res['bucketed_int8']['modeled_dcn_bytes_per_rank']} > "
        f"{res['per_leaf_int8']['modeled_dcn_bytes_per_rank']})")


def main(quick: bool = False, out: str = "BENCH_reduce.json",
         bucket_mb: float = 0.25) -> Dict[str, Any]:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pods = 2
    if quick:
        tree = synthetic_grad_tree(num_leaves=12, scale=24)
        bucket_mb = min(bucket_mb, 0.002)    # keep several buckets
        iters = 2
    else:
        tree = synthetic_grad_tree(num_leaves=48, scale=96)
        iters = 8

    res = bench_paths(tree, mesh, pods, bucket_mb, iters)
    check_invariants(res)

    lay = res["_layout"]
    print(f"[reduce_bench] {lay['leaves']} leaves, "
          f"{lay['total_bytes'] / 1e6:.2f} MB grads, "
          f"{lay['num_buckets']} buckets x {lay['bucket_elems']} elems "
          f"(bound: <= {lay['collective_bound']} cross-pod collectives)")
    hdr = (f"| {'path':14s} | colls | modeled DCN MB | avg ms | "
           f"max abs err |")
    print(hdr)
    for name in ("per_leaf", "per_leaf_int8", "bucketed", "bucketed_int8"):
        r = res[name]
        print(f"| {name:14s} | {r['collectives']:5d} | "
              f"{r['modeled_dcn_bytes_per_rank'] / 1e6:14.3f} | "
              f"{r['avg_ms']:6.2f} | {r['max_abs_err']:11.2e} |")

    res["speedup"] = {
        "collective_reduction_exact":
            res["per_leaf"]["collectives"] / res["bucketed"]["collectives"],
        "collective_reduction_int8":
            res["per_leaf_int8"]["collectives"] /
            res["bucketed_int8"]["collectives"],
        "dcn_bytes_reduction_int8":
            res["per_leaf_int8"]["modeled_dcn_bytes_per_rank"] /
            res["bucketed_int8"]["modeled_dcn_bytes_per_rank"],
    }
    with open(out, "w") as fh:
        json.dump(res, fh, indent=2)
    print(f"[reduce_bench] wrote {out}; collective reduction "
          f"{res['speedup']['collective_reduction_exact']:.0f}x exact / "
          f"{res['speedup']['collective_reduction_int8']:.0f}x int8")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small tree, 2 iters, invariant smoke check")
    ap.add_argument("--out", default="BENCH_reduce.json")
    ap.add_argument("--bucket-mb", type=float, default=0.25)
    args = ap.parse_args()
    main(quick=args.quick, out=args.out, bucket_mb=args.bucket_mb)
