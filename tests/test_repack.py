"""Checkpoint repack layer: layout-portable exact resume.

Fast tests cover the path-key escaping, the flat-stream translations
(packed <-> pytree <-> packed, bit-exact, Adam and LAMB state incl. the
flat error-feedback stack), structured meta serialization, crash
atomicity, and the consumed-row resume validation. The acceptance bar
— save under ``overlap="buckets"``, restore into a different layout /
a re-meshed pod count, and continue bit-identically — runs under a
multi-device mesh in a subprocess, per the project convention that only
children force device counts.
"""
import json
import logging
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import repack
from repro.checkpoint.checkpoint import (CheckpointCorruptError,
                                         CheckpointManager)
from repro.configs.base import OptimizerConfig
from repro.core import buckets as bkt
from repro.core import elastic
from repro.core.capacity import CapacityPlan, plan_capacities
from repro.optim import adam

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (37, 8), jnp.float32),
        "b": jax.random.normal(ks[1], (13,), jnp.float32),
        "deep": {"m": jax.random.normal(ks[2], (5, 3, 2), jnp.float32),
                 "s": jax.random.normal(ks[3], (101,), jnp.float32)},
    }


# --------------------------------------------------------------------------
# path keys
# --------------------------------------------------------------------------


def test_path_keys_escape_slashes_and_attr_keys():
    """Dict keys containing '/' cannot collide with nested paths, and
    NamedTuple fields map to bare names (not ``str(GetAttrKey)``)."""
    flat = repack.flatten_with_paths(
        {"a/b": {"c": np.ones(1)}, "a": {"b/c": np.zeros(1)}})
    assert sorted(flat) == ["a%2Fb/c", "a/b%2Fc"]

    st = adam.AdamState(step=np.int32(1), m={"w": np.ones(2)},
                        v={"w": np.ones(2)})
    keys = sorted(repack.flatten_with_paths({"opt": st}))
    assert keys == ["opt/m/w", "opt/step", "opt/v/w"]


def test_flatten_collision_raises_at_save_time(monkeypatch, tmp_path):
    """Exotic key types whose str() collides must fail the SAVE, not
    corrupt the checkpoint silently."""
    monkeypatch.setattr(repack, "path_component", lambda p: "same")
    with pytest.raises(ValueError, match="collision"):
        repack.flatten_with_paths({"a": np.ones(1), "b": np.ones(1)})
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(ValueError, match="collision"):
        mgr.save(1, {"a": jnp.ones(1), "b": jnp.ones(1)}, block=True)
    assert mgr.all_steps() == []          # nothing committed


# --------------------------------------------------------------------------
# the flat stream
# --------------------------------------------------------------------------


def test_fit_stream_pads_trims_and_rejects_nonzero_tail():
    s = np.arange(1, 5, dtype=np.float32)
    np.testing.assert_array_equal(repack.fit_stream(s, 6),
                                  [1, 2, 3, 4, 0, 0])
    padded = np.concatenate([s, np.zeros(3, np.float32)])
    np.testing.assert_array_equal(repack.fit_stream(padded, 4), s)
    with pytest.raises(ValueError, match="nonzero data"):
        repack.fit_stream(s, 3)


def test_layout_record_roundtrip_and_fingerprint():
    tree = _tree()
    lo_a = bkt.build_layout(tree, bucket_mb=1e-4, multiple_of=8)
    lo_b = bkt.build_layout(tree, bucket_mb=3e-4, multiple_of=16)
    paths = [repack.path_key(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    rec = bkt.layout_record(lo_a, leaf_paths=paths)
    back = bkt.layout_from_record(rec, treedef=lo_a.treedef)
    assert back.shapes == lo_a.shapes
    assert back.offsets == lo_a.offsets
    assert (back.num_buckets, back.bucket_elems) == (lo_a.num_buckets,
                                                     lo_a.bucket_elems)
    # the record survives a JSON round trip with a stable fingerprint
    import json
    rec2 = json.loads(json.dumps(rec))
    assert bkt.layout_fingerprint(rec2) == rec["fingerprint"]
    assert (bkt.layout_record(lo_b)["fingerprint"] != rec["fingerprint"])
    with pytest.raises(ValueError, match="newer"):
        bkt.layout_from_record({**rec, "version": 999})


# --------------------------------------------------------------------------
# repack round trips (satellite: bit-exact for Adam and LAMB, incl. the
# flat error-feedback state)
# --------------------------------------------------------------------------


class _State(adam.AdamState):
    pass


def _mk_state(params, opt, err=()):
    from typing import NamedTuple

    class TS(NamedTuple):
        params: object
        opt: object
        err: object
    return TS(params=params, opt=opt, err=err)


@pytest.mark.parametrize("opt_name", ["adamw", "lamb"])
def test_packed_pytree_packed_roundtrip_bit_exact(tmp_path, opt_name):
    """packed(A) -> pytree -> packed(B) -> packed(A): every hop exact.

    LAMB shares AdamState, so the repack must be optimizer-agnostic —
    both names run the identical translation and must stay bit-exact.
    """
    params = _tree(0)
    m_tree = jax.tree.map(lambda p: 0.3 * p + 0.01, _tree(1))
    v_tree = jax.tree.map(lambda p: jnp.abs(p) * 0.2, _tree(2))
    lo_a = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    lo_b = bkt.build_layout(params, bucket_mb=4e-4, multiple_of=32)
    assert (lo_a.num_buckets, lo_a.bucket_elems) != (lo_b.num_buckets,
                                                     lo_b.bucket_elems)
    m_a = np.asarray(bkt.pack_buckets(m_tree, lo_a))
    v_a = np.asarray(bkt.pack_buckets(v_tree, lo_a))
    step = jnp.asarray(7, jnp.int32)

    def packed_state(lo, m, v):
        return _mk_state(params, adam.AdamState(step=step, m=m, v=v))

    def tree_template():
        return _mk_state(params, adam.AdamState(
            step=step, m=jax.tree.map(jnp.zeros_like, m_tree),
            v=jax.tree.map(jnp.zeros_like, v_tree)))

    mgr = CheckpointManager(str(tmp_path / opt_name))
    rec = bkt.layout_record(lo_a)
    mgr.save(1, packed_state(lo_a, m_a, v_a),
             meta={"format": {"version": repack.FORMAT_VERSION,
                              "state": "packed",
                              "packed_fields": ["opt/m", "opt/v"],
                              "layout": rec}},
             block=True)
    # packed(A) -> pytree
    as_tree, _ = mgr.restore(tree_template())
    for got, want in zip(jax.tree.leaves(as_tree.opt.m),
                         jax.tree.leaves(m_tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(jax.tree.leaves(as_tree.opt.v),
                         jax.tree.leaves(v_tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # packed(A) -> packed(B)
    zb = jnp.zeros((lo_b.num_buckets, lo_b.bucket_elems))
    as_b, _ = mgr.restore(packed_state(lo_b, zb, zb))
    np.testing.assert_array_equal(
        np.asarray(as_b.opt.m),
        np.asarray(bkt.pack_buckets(m_tree, lo_b)))
    # pytree -> packed(B) (save the unpacked form, restore packed)
    mgr2 = CheckpointManager(str(tmp_path / (opt_name + "_tree")))
    mgr2.save(2, as_tree, block=True)
    back_b, _ = mgr2.restore(packed_state(lo_b, zb, zb))
    np.testing.assert_array_equal(
        np.asarray(back_b.opt.m),
        np.asarray(bkt.pack_buckets(m_tree, lo_b)))
    # packed(B) -> packed(A) closes the loop
    mgr3 = CheckpointManager(str(tmp_path / (opt_name + "_b")))
    mgr3.save(3, back_b, block=True)
    back_a, _ = mgr3.restore(packed_state(
        lo_a, jnp.zeros_like(m_a), jnp.zeros_like(v_a)))
    np.testing.assert_array_equal(np.asarray(back_a.opt.m), m_a)
    np.testing.assert_array_equal(np.asarray(back_a.opt.v), v_a)


def test_err_state_repack_same_ranks_exact_rank_change_conserves(
        tmp_path):
    params = _tree(0)
    lo_a = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    lo_b = bkt.build_layout(params, bucket_mb=4e-4, multiple_of=32)
    rng = np.random.default_rng(0)
    err = np.zeros((2, lo_a.num_buckets, lo_a.bucket_elems), np.float32)
    # data region random, padding tail stays zero (the reachable state)
    flat = rng.standard_normal((2, lo_a.total)).astype(np.float32)
    err.reshape(2, -1)[:, :lo_a.total] = flat
    state = _mk_state(params, adam.AdamState(
        step=jnp.int32(1),
        m=jnp.asarray(bkt.pack_buckets(params, lo_a)),
        v=jnp.asarray(bkt.pack_buckets(params, lo_a))), err=err)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, block=True)

    zb = jnp.zeros((lo_b.num_buckets, lo_b.bucket_elems))
    tmpl_same = _mk_state(params, adam.AdamState(step=jnp.int32(1),
                                                 m=zb, v=zb),
                          err=np.zeros((2, lo_b.num_buckets,
                                        lo_b.bucket_elems), np.float32))
    got, _ = mgr.restore(tmpl_same)
    np.testing.assert_array_equal(
        np.asarray(got.err).reshape(2, -1)[:, :lo_a.total], flat)
    # rank-count change: per-rank split has no exact image — the SUM
    # (the quantity that re-enters future gradients) is conserved on
    # rank 0
    tmpl_one = tmpl_same._replace(err=np.zeros(
        (1, lo_b.num_buckets, lo_b.bucket_elems), np.float32))
    got1, _ = mgr.restore(tmpl_one)
    np.testing.assert_allclose(
        np.asarray(got1.err).reshape(1, -1)[0, :lo_a.total],
        flat.sum(axis=0), rtol=1e-6)   # 1 target rank: sum = its extent
    # a checkpoint without residual state restores with FRESH zeros
    mgr2 = CheckpointManager(str(tmp_path / "noerr"))
    mgr2.save(1, _mk_state(params, adam.AdamState(
        step=jnp.int32(1), m=zb, v=zb)), block=True)
    fresh, _ = mgr2.restore(tmpl_same)
    assert not np.asarray(fresh.err).any()


# --------------------------------------------------------------------------
# v3 per-host sharded saves + crash-consistent manifests (tentpole)
# --------------------------------------------------------------------------


def _packed_state(lo, seed=0, err_ranks=2):
    params = _tree(seed)
    m = bkt.pack_buckets(
        jax.tree.map(lambda p: 0.3 * p + 0.01, _tree(seed + 1)), lo)
    v = bkt.pack_buckets(
        jax.tree.map(lambda p: jnp.abs(p) * 0.2, _tree(seed + 2)), lo)
    err = np.zeros((err_ranks, lo.num_buckets, lo.bucket_elems),
                   np.float32)
    rng = np.random.default_rng(seed)
    err.reshape(err_ranks, -1)[:, :lo.total] = rng.standard_normal(
        (err_ranks, lo.total)).astype(np.float32)
    return _mk_state(params, adam.AdamState(step=jnp.int32(3), m=m, v=v),
                     err=err)


def _fmt_for(lo, hosts):
    return {"version": repack.FORMAT_VERSION, "state": "packed",
            "packed_fields": ["opt/m", "opt/v"],
            "layout": bkt.layout_record(lo, hosts=hosts),
            "hosts": hosts, "overlap": "buckets"}


def test_host_shard_extents_balanced_and_recorded():
    assert bkt.host_shard_extents(10, 3) == ((0, 4), (4, 7), (7, 10))
    assert bkt.host_shard_extents(2, 4) == ((0, 1), (1, 2), (2, 2),
                                            (2, 2))
    with pytest.raises(ValueError, match="hosts"):
        bkt.host_shard_extents(5, 0)
    lo = bkt.build_layout(_tree(), bucket_mb=1e-4, multiple_of=8)
    rec = bkt.layout_record(lo, hosts=2)
    assert rec["hosts"] == 2
    assert [tuple(e) for e in rec["host_extents"]] == \
        list(bkt.host_shard_extents(lo.num_buckets, 2))
    # extents are write-time provenance, not grid: fingerprint unchanged
    assert rec["fingerprint"] == bkt.layout_record(lo)["fingerprint"]


def test_v3_sharded_save_matches_gathered_v2_bit_exact(tmp_path):
    """Tentpole acceptance: each host writes only its own shard file,
    the manifest records sizes/checksums/extents, and restore through
    the assembled stream is bit-identical to a gathered v2 save of the
    same state — into the same grid, a re-gridded packed layout, and
    the pytree (non-overlap) layout."""
    params = _tree(0)
    lo_a = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    lo_b = bkt.build_layout(params, bucket_mb=4e-4, multiple_of=32)
    state = _packed_state(lo_a)
    fmt = _fmt_for(lo_a, hosts=2)

    mgr2 = CheckpointManager(str(tmp_path / "v2"))
    mgr2.save(1, state, meta={"format": dict(fmt)}, format_version=2,
              block=True)
    mgr3 = CheckpointManager(str(tmp_path / "v3"))
    mgr3.save(1, state, meta={"format": dict(fmt)}, block=True)

    d3 = tmp_path / "v3" / "step_0000000001"
    assert (d3 / "manifest.json").exists()
    assert (d3 / "arrays_host0.npz").exists()
    assert (d3 / "arrays_host1.npz").exists()
    assert not (d3 / "arrays.npz").exists()
    d2 = tmp_path / "v2" / "step_0000000001"
    assert (d2 / "arrays.npz").exists()
    assert not (d2 / "manifest.json").exists()

    man = json.loads((d3 / "manifest.json").read_text())
    assert man["hosts"] == 2 and man["format_version"] == 3
    assert "meta.json" in man["files"]
    for fname, rec in man["files"].items():
        assert (d3 / fname).stat().st_size == rec["bytes"]
        assert len(rec["sha256"]) == 64
    # packed stacks split by bucket rows along the layout extents,
    # the err stack by rank
    h0 = man["files"]["arrays_host0.npz"]["keys"]
    h1 = man["files"]["arrays_host1.npz"]["keys"]
    ext = fmt["layout"]["host_extents"]
    assert h0["opt/m"]["rows"] == ext[0]
    assert h1["opt/m"]["rows"] == ext[1]
    assert h0["err"]["rows"] == [0, 1] and h1["err"]["rows"] == [1, 2]

    zb_a = jnp.zeros((lo_a.num_buckets, lo_a.bucket_elems))
    zb_b = jnp.zeros((lo_b.num_buckets, lo_b.bucket_elems))
    err_a = np.zeros((2, lo_a.num_buckets, lo_a.bucket_elems),
                     np.float32)
    err_b = np.zeros((2, lo_b.num_buckets, lo_b.bucket_elems),
                     np.float32)
    templates = {
        "packed-same": _mk_state(params, adam.AdamState(
            step=jnp.int32(0), m=zb_a, v=zb_a), err=err_a),
        "packed-regrid": _mk_state(params, adam.AdamState(
            step=jnp.int32(0), m=zb_b, v=zb_b), err=err_b),
        "pytree": _mk_state(params, adam.AdamState(
            step=jnp.int32(0),
            m=jax.tree.map(jnp.zeros_like, params),
            v=jax.tree.map(jnp.zeros_like, params)), err=err_a),
    }
    for tag, tmpl in templates.items():
        a, _ = mgr2.restore(tmpl)
        b, _ = mgr3.restore(tmpl)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=tag)


def test_v2_to_v3_migration_roundtrip_bit_exact(tmp_path):
    """A legacy gathered v2 checkpoint restores, re-saves as sharded
    v3, and restores again — every leaf bit-identical to the source."""
    params = _tree(0)
    lo = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    state = _packed_state(lo)
    fmt = _fmt_for(lo, hosts=2)
    tmpl = jax.tree.map(np.zeros_like, jax.device_get(state))

    old = CheckpointManager(str(tmp_path / "old"))
    old.save(1, state, meta={"format": dict(fmt)}, format_version=2,
             block=True)
    from_v2, meta_v2 = old.restore(tmpl)
    assert meta_v2["format"]["version"] == 2

    new = CheckpointManager(str(tmp_path / "new"))
    new.save(1, from_v2, meta={"format": dict(fmt)}, block=True)
    from_v3, meta_v3 = new.restore(tmpl)
    assert meta_v3["format"]["version"] == 3
    for x, y in zip(jax.tree.leaves(jax.device_get(state)),
                    jax.tree.leaves(from_v3)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("corrupt", ["truncate", "flip", "del_manifest"])
def test_v3_fault_injection_rejects_step_and_falls_back(tmp_path,
                                                        corrupt, caplog):
    """Durability satellite: truncate a shard / flip a byte / delete
    manifest.json after commit — restore rejects the step via the
    manifest validation and falls back to the previous committed one;
    an explicitly requested corrupt step raises."""
    params = _tree(0)
    lo = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    fmt = _fmt_for(lo, hosts=2)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    s1 = _packed_state(lo, seed=0)
    s2 = _packed_state(lo, seed=7)
    mgr.save(1, s1, meta={"format": dict(fmt)}, block=True)
    mgr.save(2, s2, meta={"format": dict(fmt)}, block=True)

    d2 = tmp_path / "step_0000000002"
    shard = d2 / "arrays_host1.npz"
    if corrupt == "truncate":
        shard.write_bytes(shard.read_bytes()[:shard.stat().st_size // 2])
    elif corrupt == "flip":
        data = bytearray(shard.read_bytes())
        data[len(data) // 2] ^= 0xFF
        shard.write_bytes(bytes(data))
    else:
        (d2 / "manifest.json").unlink()

    zb = jnp.zeros((lo.num_buckets, lo.bucket_elems))
    tmpl = _mk_state(params, adam.AdamState(step=jnp.int32(0), m=zb,
                                            v=zb),
                     err=np.zeros((2, lo.num_buckets, lo.bucket_elems),
                                  np.float32))
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(tmpl, step=2)
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        got, meta = mgr.restore(tmpl)
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(got.opt.m),
                                  np.asarray(s1.opt.m))
    assert any("falling back" in r.message for r in caplog.records)


def test_restore_rejects_lossy_dtype_cast_unless_allowed(tmp_path,
                                                         caplog):
    """`_unflatten_like` no longer astype()s silently: fp32 ckpt into a
    bf16 template raises unless allow_cast=True, and ANY cast logs."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.arange(4.0, dtype=jnp.float32)}, block=True)
    narrow = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    with pytest.raises(ValueError, match="lossy dtype cast"):
        mgr.restore(narrow)
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        got, _ = mgr.restore(narrow, allow_cast=True)
    assert np.asarray(got["w"]).dtype == jnp.bfloat16
    assert any("cast" in r.message for r in caplog.records)
    # widening is lossless: allowed without the flag, still logged
    caplog.clear()
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        wide, _ = mgr.restore({"w": jax.ShapeDtypeStruct((4,),
                                                         np.float64)})
    assert np.asarray(wide["w"]).dtype == np.float64
    assert any("cast" in r.message for r in caplog.records)
    # same dtype: no cast, no log
    caplog.clear()
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        mgr.restore({"w": jax.ShapeDtypeStruct((4,), np.float32)})
    assert not caplog.records


def test_all_steps_skips_stray_entries_with_one_warning(tmp_path,
                                                        caplog):
    """Stray step_* entries (editor leftovers) are skipped with a
    warning instead of crashing int() — and warned only once."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(2)}, block=True)
    os.makedirs(str(tmp_path / "step_00000000xx"))
    (tmp_path / "step_editor.swp").write_text("junk")
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        assert mgr.all_steps() == [1]
    assert sum("non-checkpoint" in r.message
               for r in caplog.records) == 2
    caplog.clear()
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        assert mgr.all_steps() == [1]
    assert not caplog.records


def test_err_rank_change_distributes_sum_across_new_ranks(tmp_path):
    """Re-mesh residual bugfix: the summed residual is partitioned over
    the NEW ranks' contiguous stream extents — sum conserved
    bit-exactly, every destination rank carries a share, no rank parked
    with the whole fleet's residual (the old rank-0 behavior)."""
    params = _tree(0)
    lo = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    state = _packed_state(lo, seed=3, err_ranks=4)
    flat = np.asarray(state.err).reshape(4, -1)[:, :lo.total].copy()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, block=True)

    zb = jnp.zeros((lo.num_buckets, lo.bucket_elems))
    tmpl = _mk_state(params, adam.AdamState(step=jnp.int32(1), m=zb,
                                            v=zb),
                     err=np.zeros((2, lo.num_buckets, lo.bucket_elems),
                                  np.float32))
    got, _ = mgr.restore(tmpl)
    got_err = np.asarray(got.err).reshape(2, -1)
    np.testing.assert_array_equal(got_err.sum(axis=0)[:lo.total],
                                  flat.sum(axis=0))
    exts = bkt.host_shard_extents(lo.padded_total, 2)
    for r, (lo_e, hi_e) in enumerate(exts):
        assert np.abs(got_err[r, lo_e:min(hi_e, lo.total)]).sum() > 0, \
            f"rank {r} restarted with an empty residual share"
        outside = np.concatenate([got_err[r, :lo_e], got_err[r, hi_e:]])
        assert not outside.any(), \
            f"rank {r} holds residual outside its extent"


# --------------------------------------------------------------------------
# meta serialization + crash atomicity (satellites)
# --------------------------------------------------------------------------


def test_meta_plan_roundtrips_structured(tmp_path):
    """No more default=str: the plan comes back as a real CapacityPlan
    and numpy values as JSON numbers."""
    mgr = CheckpointManager(str(tmp_path))
    plan = plan_capacities(16, [2, 1, 1])
    mgr.save(5, {"w": jnp.ones(2)},
             meta={"plan": plan, "epoch": np.int64(3),
                   "caps": np.asarray([2.0, 1.0])}, block=True)
    _, meta = mgr.restore({"w": jnp.ones(2)})
    got = meta["plan"]
    assert isinstance(got, CapacityPlan)
    np.testing.assert_array_equal(got.rows_per_rank, plan.rows_per_rank)
    np.testing.assert_array_equal(got.capacities, plan.capacities)
    assert got.buffer_rows == plan.buffer_rows
    assert got.global_rows == plan.global_rows
    assert meta["epoch"] == 3 and meta["caps"] == [2.0, 1.0]
    # the restored plan is USABLE, not a string
    assert got.row_weights().shape == (3, plan.buffer_rows)


def test_adapt_arrays_validates_pipeline_stage_block(tmp_path):
    """A checkpoint's recorded pipeline stage plan is placement
    metadata — params are per-leaf, so restoring across stage plans
    needs NO translation and must round-trip bit-exactly — but a
    malformed record means the writer was broken, and the restore must
    fail loudly instead of resuming from a suspect checkpoint."""
    from repro.core import pipeline as pipe

    rec = pipe.stage_record(pipe.plan_stages(4, (3.0, 1.0)))
    tree = _tree(3)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, tree,
             meta={"format": {"version": repack.FORMAT_VERSION,
                              "state": "pytree", "packed_fields": [],
                              "layout": None, "pipeline": rec}},
             block=True)
    got, meta = mgr.restore(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the record survives JSON round-trip into a usable StagePlan
    back = pipe.stage_from_record(meta["format"]["pipeline"])
    assert back.layers_per_stage.tolist() == [3, 1]

    # malformed blocks fail adapt loudly (broken writer)
    arrays = repack.flatten_with_paths(
        jax.tree.map(np.asarray, tree))
    ok = repack.adapt_arrays(dict(arrays), tree,
                             fmt={"pipeline": rec})
    assert set(ok) == set(arrays)
    for bad in ("stages=2",
                {"num_layers": 4},
                {"num_layers": 5, "plan": rec["plan"]}):
        with pytest.raises(ValueError, match="malformed|sums to"):
            repack.adapt_arrays(dict(arrays), tree,
                                fmt={"pipeline": bad})


def test_meta_unserializable_value_fails_loudly(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(TypeError, match="not JSON-serializable"):
        mgr.save(1, {"w": jnp.ones(2)}, meta={"bad": {1, 2}}, block=True)


def test_interrupted_write_leaves_no_done_and_restore_skips(tmp_path,
                                                            monkeypatch):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.arange(4.0)}
    mgr.save(1, state, block=True)

    # crash mid-write (after arrays.npz, before _DONE): no commit marker
    real_savez = np.savez

    def boom(path, **kw):
        real_savez(path, **kw)
        raise RuntimeError("disk died")
    monkeypatch.setattr(np, "savez", boom)
    mgr.save(2, {"w": jnp.arange(4.0) * 2})
    with pytest.raises(RuntimeError, match="disk died"):
        mgr.wait()
    monkeypatch.setattr(np, "savez", real_savez)

    assert mgr.all_steps() == [1]          # step 2 never committed
    restored, meta = mgr.restore(state)
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(4.0))
    # a half-renamed dir without _DONE is also ignored
    os.makedirs(str(tmp_path / "step_0000000009"))
    assert mgr.latest_step() == 1


# --------------------------------------------------------------------------
# consumed-row resume validation (satellite)
# --------------------------------------------------------------------------


def test_validate_resume_equivalence_checks_assignment_not_just_total():
    a = plan_capacities(16, [1, 1, 1, 1])
    b = plan_capacities(16, [1, 1])        # re-meshed: fewer ranks, OK
    assert elastic.validate_resume_equivalence(a, b)
    assert not elastic.validate_resume_equivalence(
        a, plan_capacities(12, [1, 1]))    # different global prefix
    # same global_rows but rows that do NOT partition the prefix: the
    # old global_rows-only check passed these
    broken = CapacityPlan(capacities=np.ones(2, np.float32),
                          rows_per_rank=np.asarray([10, 4], np.int64),
                          buffer_rows=8, global_rows=16)
    assert not elastic.validate_resume_equivalence(a, broken)
    dropped = CapacityPlan(capacities=np.ones(2, np.float32),
                           rows_per_rank=np.asarray([8, 4], np.int64),
                           buffer_rows=8, global_rows=16)
    assert not elastic.validate_resume_equivalence(a, dropped)


def test_plan_remesh_buffer_divides_post_scale_accum():
    """The restart multiplies accum_steps by accum_scale, so the new
    buffer must divide by the PRODUCT (a max() left accum 2 x scale 2
    = 4 microbatches over a buffer rounded to 2)."""
    topo = elastic.MeshTopology(pods=2, data_per_pod=2, model=1)
    dec = elastic.plan_remesh(topo, [0], global_rows=12,
                              round_buffer_to=2)
    assert dec.restart_required and dec.accum_scale == 2
    assert dec.plan.buffer_rows % (2 * dec.accum_scale) == 0
    assert dec.plan.global_rows == 12


def test_checkpoint_format_block_records_layout():
    import dataclasses
    from repro.configs import base as cfgs
    from repro.configs.base import HetConfig, TrainConfig
    from repro.launch import steps
    from repro.models.model import build_model

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = cfgs.smoke_config("olmo-1b")
    model = build_model(cfg)
    packed = TrainConfig(model=cfg, het=HetConfig(
        overlap="buckets", grad_reduction="bucketed_allreduce",
        bucket_mb=0.05))
    fmt = steps.checkpoint_format(model, packed, mesh)
    assert fmt["state"] == "packed"
    assert fmt["packed_fields"] == ["opt/m", "opt/v"]
    lo = steps.bucket_layout(model, packed, mesh)
    assert fmt["layout"]["num_buckets"] == lo.num_buckets
    assert fmt["fingerprint"] == fmt["layout"]["fingerprint"]
    assert len(fmt["layout"]["leaf_paths"]) == len(lo.sizes)

    plain = TrainConfig(model=cfg, het=HetConfig())
    fmt2 = steps.checkpoint_format(model, plain, mesh)
    assert fmt2["state"] == "pytree" and fmt2["layout"] is None


# --------------------------------------------------------------------------
# the acceptance bar: overlap checkpoint -> three-way restore
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_overlap_checkpoint_three_way_restore_bit_identical():
    """Save under overlap="buckets"; restore into (i) overlap="none",
    (ii) a different bucket_mb, (iii) a re-meshed pod count after
    plan_remesh (accum-scaled to preserve the microbatch grid). In all
    three the continued trajectory is bit-identical to the
    uninterrupted run."""
    out = run_child("""
        import dataclasses, tempfile
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro.launch.sharding import named
        from repro import compat
        from repro.core import capacity, dummy, elastic
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.data import synthetic

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        shape = ShapeConfig("t", 16, 2, "train")
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, grad_clip=0.0)
        rec = synthetic.make_lm_records(6, 17, cfg.vocab_size, seed=5)

        def tcfg_for(bucket_mb, overlap, accum=1):
            return TrainConfig(model=cfg, shape=shape,
                het=HetConfig(grad_reduction="bucketed_allreduce",
                              bucket_mb=bucket_mb, overlap=overlap,
                              accum_steps=accum),
                optimizer=ocfg)

        def batch_for(plan, lo, hi):
            packed = dummy.pack_global_batch(
                {"inputs": rec["inputs"][lo:hi, :16],
                 "labels": rec["labels"][lo:hi, :16]}, plan)
            return {k: jnp.asarray(v) for k, v in packed.items()}

        # uninterrupted run: 2-pod mesh, overlap pipeline, ckpt @ step 1
        meshA = jax.make_mesh((2, 1, 2), ("pod", "data", "model"))
        topoA = elastic.MeshTopology(pods=2, data_per_pod=1, model=2)
        planA = capacity.plan_capacities(2, [1, 1])
        tA = tcfg_for(0.05, "buckets")
        with compat.set_mesh(meshA):
            st = steps.init_train_state(m, tA, meshA,
                                        jax.random.PRNGKey(0))
            fA = steps.build_train_step(m, tA, meshA)
            st, _ = fA(st, batch_for(planA, 0, 2))
            host1 = jax.device_get(st)
            st, met2 = fA(st, batch_for(planA, 2, 4))
            st, met3 = fA(st, batch_for(planA, 4, 6))
        ref = jax.device_get(st)
        ref_losses = (float(met2["loss"]), float(met3["loss"]))

        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(1, host1,
                 meta={"plan": planA,
                       "format": steps.checkpoint_format(m, tA, meshA)},
                 block=True)

        def resume(tcfg, mesh, plan):
            host, meta = mgr.restore(steps.state_shapes(m, tcfg, mesh))
            assert elastic.validate_resume_equivalence(meta["plan"],
                                                       plan)
            with compat.set_mesh(mesh):
                sr = jax.device_put(
                    host, named(mesh, steps.state_specs(m, tcfg, mesh)))
                f = steps.build_train_step(m, tcfg, mesh)
                sr, m2 = f(sr, batch_for(plan, 2, 4))
                sr, m3 = f(sr, batch_for(plan, 4, 6))
            return (jax.device_get(sr),
                    (float(m2["loss"]), float(m3["loss"])))

        def assert_bitwise(got, losses, tag):
            assert losses == ref_losses, (tag, losses, ref_losses)
            for a, b in zip(jax.tree.leaves(ref.params),
                            jax.tree.leaves(got.params)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b), err_msg=tag)
            print(tag, "bit-identical")

        # (i) overlap="none": moments unpack into the pytree layout
        got, losses = resume(tcfg_for(0.05, "none"), meshA, planA)
        assert_bitwise(got, losses, "overlap->none")
        # moments too: repacked pytree moments == uninterrupted packed
        lo = steps.bucket_layout(m, tA, meshA)
        from repro.core import buckets as bkt
        np.testing.assert_array_equal(
            np.asarray(bkt.pack_buckets(got.opt.m, lo)),
            np.asarray(ref.opt.m))

        # (ii) different bucket_mb: packed -> packed re-grid
        tB = tcfg_for(0.02, "buckets")
        loB = steps.bucket_layout(m, tB, meshA)
        assert (loB.num_buckets, loB.bucket_elems) != \\
            (lo.num_buckets, lo.bucket_elems)
        got, losses = resume(tB, meshA, planA)
        assert_bitwise(got, losses, "bucket_mb regrid")

        # (iii) pod lost -> plan_remesh -> 1-pod mesh, accum-scaled to
        # preserve the microbatch grid (elastic.RemeshDecision)
        dec = elastic.plan_remesh(topoA, [0], planA.global_rows)
        assert dec.restart_required and dec.accum_scale == 2
        assert elastic.validate_resume_equivalence(planA, dec.plan)
        meshC = jax.make_mesh(dec.topology.mesh_shape(),
                              dec.topology.mesh_axes())
        tC = tcfg_for(0.02, "buckets", accum=dec.accum_scale)
        loC = steps.bucket_layout(m, tC, meshC)
        assert (loC.num_buckets, loC.bucket_elems) != \\
            (lo.num_buckets, lo.bucket_elems)        # re-grid too
        got, losses = resume(tC, meshC, dec.plan)
        assert_bitwise(got, losses, "re-mesh 2pods->1pod")
        print("OK")
        """, devices=4, timeout=1200)
    assert "OK" in out


@pytest.mark.slow
def test_train_driver_elastic_restart_with_repack(tmp_path):
    """Full driver: overlap checkpoints on a 2-pod mesh, a pod dies
    (--kill-pod), soft replanning overflows -> RemeshRequired -> the
    driver re-meshes via plan_remesh, repacks the packed optimizer
    state into the new bucket grid, and finishes the step budget."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "olmo-1b", "--smoke", "--steps", "12",
         "--global-batch", "16", "--seq-len", "16",
         "--devices", "2,2,2",
         "--grad-reduction", "bucketed_allreduce",
         "--bucket-mb", "0.05", "--overlap", "buckets",
         "--replan-interval", "8", "--ckpt-every", "4",
         "--kill-pod", "1@5", "--log-every", "4",
         "--ckpt-dir", str(tmp_path / "ckpt"),
         "--data-dir", str(tmp_path / "data")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = proc.stdout
    assert "remesh:" in out and "re-meshed to" in out, out
    assert "accum_steps scaled x2" in out, out
    assert "done:" in out, out
