"""End-to-end system tests: the CLI train/serve drivers (subprocess)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(args, devices=4, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_train_driver_heterogeneous(tmp_path):
    """Full pipeline: synthetic shards -> het plan (one dead rank) ->
    prefetch -> SPMD step -> checkpoint; loss must decrease."""
    out = run_cli([
        "repro.launch.train", "--arch", "olmo-1b", "--smoke",
        "--steps", "25", "--global-batch", "16", "--seq-len", "48",
        "--capacities", "2,1,1,0", "--devices", "4,1",
        "--log-every", "10", "--ckpt-every", "20",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--data-dir", str(tmp_path / "data"),
    ])
    assert "plan rows" in out
    lines = [l for l in out.splitlines() if l.startswith("[train] done")]
    assert lines, out
    first, last = [float(x) for x in
                   lines[0].split("loss")[1].strip().split(" -> ")]
    assert last < first
    # checkpoint rotation happened
    assert any(p.startswith("step_") for p in
               os.listdir(tmp_path / "ckpt"))


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    run_cli([
        "repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
        "--steps", "10", "--global-batch", "8", "--seq-len", "32",
        "--devices", "2,2", "--ckpt-every", "10",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--data-dir", str(tmp_path / "data")])
    out = run_cli([
        "repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
        "--steps", "15", "--global-batch", "8", "--seq-len", "32",
        "--devices", "2,2", "--resume",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--data-dir", str(tmp_path / "data")])
    assert "resumed from step 10" in out


@pytest.mark.slow
def test_serve_driver(tmp_path):
    """The continuous-batching serve driver end to end on a DP mesh
    with skewed pod speeds: every request completes and the engine
    reports the modeled throughput/latency stats."""
    out = run_cli([
        "repro.launch.serve", "--arch", "tinyllama-1.1b", "--smoke",
        "--slots", "4", "--prefill-batch", "2", "--requests", "8",
        "--max-prompt", "24", "--max-gen", "16",
        "--pod-speeds", "1,0.5", "--devices", "2,2"])
    assert "8 requests" in out
    assert "tok/unit" in out
    assert "decode steps" in out


@pytest.mark.slow
def test_train_driver_hierarchical_int8(tmp_path):
    out = run_cli([
        "repro.launch.train", "--arch", "olmo-1b", "--smoke",
        "--steps", "12", "--global-batch", "16", "--seq-len", "32",
        "--devices", "2,2,2", "--grad-reduction", "hierarchical",
        "--compression", "int8", "--accum", "2",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--data-dir", str(tmp_path / "data")], devices=8)
    lines = [l for l in out.splitlines() if l.startswith("[train] done")]
    first, last = [float(x) for x in
                   lines[0].split("loss")[1].strip().split(" -> ")]
    assert last < first
