"""core/chaos.py — deterministic fault injection.

Covers the schedule format (JSON round-trip, validation, presets), the
engine's per-(step, rank) semantics (slowdown windows, kill scope,
seeded flaky drops), the two integration surfaces (step_times ->
StragglerMonitor, ckpt_fault_hook -> CheckpointManager bounded retry),
and the after_remesh renumbering. Everything here must be replayable:
the same (schedule, seed, topology) always produces the same trace.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import capacity, chaos, straggler


def _engine(events, num_ranks=4, data_per_pod=2, seed=0, speeds=None):
    return chaos.ChaosEngine(
        chaos.ChaosSchedule(events=tuple(events), seed=seed),
        num_ranks=num_ranks, data_per_pod=data_per_pod, speeds=speeds)


# --------------------------------------------------------------------------
# schedule: validation + JSON round-trip
# --------------------------------------------------------------------------


def test_fault_validation_errors():
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.Fault("meteor").validate()
    with pytest.raises(ValueError, match="factor > 0"):
        chaos.slowdown(rank=0, factor=0.0).validate()
    with pytest.raises(ValueError, match="exactly one of"):
        chaos.Fault("kill", rank=1, pod=0, step=3).validate()
    with pytest.raises(ValueError, match="exactly one of"):
        chaos.Fault("kill", step=3).validate()
    with pytest.raises(ValueError, match="needs step"):
        chaos.Fault("kill", rank=1, step=None).validate()
    with pytest.raises(ValueError, match="drop_prob"):
        chaos.flaky(rank=0, drop_prob=1.5).validate()
    with pytest.raises(ValueError, match="mode"):
        chaos.ckpt_io_fail(mode="intermittent").validate()
    with pytest.raises(ValueError, match="fails >= 1"):
        chaos.ckpt_io_fail(fails=0).validate()


def test_schedule_json_round_trip():
    sched = chaos.ChaosSchedule(events=(
        chaos.slowdown(1, factor=3.0, start=5, duration=20),
        chaos.kill(pod=1, step=40),
        chaos.flaky(0, drop_prob=0.25, start=0, duration=10),
        chaos.ckpt_io_fail(step=12, mode="persistent", fails=1),
    ), seed=7)
    again = chaos.ChaosSchedule.from_json(sched.to_json())
    assert again == sched
    # and the record is plain JSON (no numpy types, no None noise)
    rec = json.loads(sched.to_json())
    assert rec["seed"] == 7
    assert all("rank" not in e or isinstance(e["rank"], int)
               for e in rec["events"])


def test_schedule_rejects_unknown_fields_and_kinds():
    with pytest.raises(ValueError, match="unknown fault field"):
        chaos.ChaosSchedule.from_record(
            {"events": [{"kind": "kill", "rank": 0, "step": 1,
                         "sevrity": 9}]})
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.ChaosSchedule.from_record(
            {"events": [{"kind": "gamma_ray", "rank": 0}]})


def test_load_schedule_preset_path_and_unknown(tmp_path):
    sched = chaos.load_schedule("dead-rank", num_ranks=4,
                                data_per_pod=2, total_steps=30)
    assert [e.kind for e in sched.events] == ["kill"]
    assert sched.events[0].rank == 3 and sched.events[0].step == 10

    p = tmp_path / "sched.json"
    p.write_text(chaos.ChaosSchedule(
        events=(chaos.slowdown(0, 2.0),), seed=3).to_json())
    loaded = chaos.load_schedule(str(p), num_ranks=2)
    assert loaded.seed == 3 and loaded.events[0].kind == "slowdown"

    with pytest.raises(ValueError, match="neither a schedule.json"):
        chaos.load_schedule("black-swan", num_ranks=2)


def test_presets_build_valid_engines_on_any_topology():
    for name, build in chaos.PRESETS.items():
        for n, dpp in ((1, 1), (4, 2), (6, 3)):
            sched = chaos.ChaosSchedule(events=build(n, dpp, 20))
            sched.validate()
            chaos.ChaosEngine(sched, num_ranks=n, data_per_pod=dpp)


# --------------------------------------------------------------------------
# engine: per-(step, rank) semantics
# --------------------------------------------------------------------------


def test_engine_rejects_out_of_range_targets():
    with pytest.raises(ValueError, match="rank 7 out of range"):
        _engine([chaos.slowdown(7, 2.0)])
    with pytest.raises(ValueError, match="pod 2 out of range"):
        _engine([chaos.kill(pod=2, step=1)])


def test_slowdown_window_and_stacking():
    eng = _engine([chaos.slowdown(1, 3.0, start=5, duration=10),
                   chaos.slowdown(1, 2.0, start=8)])
    assert eng.slowdown_factor(4, 1) == 1.0
    assert eng.slowdown_factor(5, 1) == 3.0
    assert eng.slowdown_factor(9, 1) == 6.0          # overlapping: product
    assert eng.slowdown_factor(15, 1) == 2.0         # first window closed
    assert eng.slowdown_factor(9, 0) == 1.0          # other ranks untouched


def test_kill_scope_rank_vs_pod():
    eng = _engine([chaos.kill(rank=0, step=3), chaos.kill(pod=1, step=5)])
    assert not eng.killed(2, 0) and eng.killed(3, 0)
    # pod 1 = ranks 2,3 (data_per_pod=2); dead from step 5, forever
    for r in (2, 3):
        assert not eng.killed(4, r)
        assert eng.killed(5, r) and eng.killed(100, r)
    assert not eng.killed(100, 1)


def test_flaky_drops_are_seed_deterministic():
    ev = [chaos.flaky(2, drop_prob=0.5, start=0, duration=200)]
    a = [_engine(ev, seed=11).dropped(s, 2) for s in range(200)]
    b = [_engine(ev, seed=11).dropped(s, 2) for s in range(200)]
    c = [_engine(ev, seed=12).dropped(s, 2) for s in range(200)]
    assert a == b                         # pure in (seed, step, rank)
    assert a != c                         # the seed actually matters
    assert 0 < sum(a) < 200               # drop_prob=0.5 drops *some*
    assert not any(_engine(ev, seed=11).dropped(s, 0) for s in range(200))


def test_step_times_fixed_point_and_slowdown():
    # rows proportional to speed => every alive rank reports the same
    # modeled time (the replan fixed point: the feed converges)
    eng = _engine([], speeds=[2.0, 1.0, 1.0, 2.0])
    times = eng.step_times(0, [4, 2, 2, 4], measured=0.5)
    np.testing.assert_allclose(times, [0.5] * 4)
    # a 4x slowdown shows up as exactly 4x that rank's time
    eng2 = _engine([chaos.slowdown(1, 4.0)], speeds=[2.0, 1.0, 1.0, 2.0])
    t2 = eng2.step_times(0, [4, 2, 2, 4], measured=0.5)
    np.testing.assert_allclose(t2, [0.5, 2.0, 0.5, 0.5])


def test_step_times_none_for_killed_and_dropped():
    eng = _engine([chaos.kill(rank=3, step=2),
                   chaos.flaky(0, drop_prob=1.0, start=4, duration=1)])
    t = eng.step_times(2, [2, 2, 2, 2], measured=1.0)
    assert t[3] is None and all(x is not None for x in t[:3])
    t4 = eng.step_times(4, [2, 2, 2, 2], measured=1.0)
    assert t4[0] is None                  # drop window covers step 4 only
    assert eng.step_times(5, [2, 2, 2, 2], 1.0)[0] is not None


def test_modeled_wall_excludes_killed_and_tracks_slowdown():
    eng = _engine([chaos.slowdown(0, 5.0, start=2),
                   chaos.kill(rank=0, step=6)])
    rows = [2, 2, 2, 2]
    assert eng.modeled_step_wall(0, rows) == pytest.approx(2.0)
    assert eng.modeled_step_wall(2, rows) == pytest.approx(10.0)
    # once the straggler is dead it no longer gates the sync step
    assert eng.modeled_step_wall(6, rows) == pytest.approx(2.0)


def test_trace_replays_byte_identically():
    ev = [chaos.slowdown(1, 3.0, start=2),
          chaos.flaky(0, drop_prob=0.3, start=0, duration=30),
          chaos.kill(pod=1, step=20)]
    t1 = _engine(ev, seed=5).trace(30, [3, 3, 3, 3])
    t2 = _engine(ev, seed=5).trace(30, [3, 3, 3, 3])
    assert json.dumps(t1) == json.dumps(t2)


# --------------------------------------------------------------------------
# integration: straggler monitor feed
# --------------------------------------------------------------------------


def test_kill_feeds_monitor_to_immediate_replan():
    eng = _engine([chaos.kill(rank=3, step=4)])
    mon = straggler.StragglerMonitor(num_ranks=4, replan_interval=100,
                                     dead_timeout_steps=2)
    plan = capacity.homogeneous_plan(8, 4, headroom=2.0)
    fired = None
    for s in range(6):
        mon.observe(eng.step_times(s, plan.rows_per_rank, 1.0))
        if mon.should_replan():
            fired = s
            break
    # dead at 4, timeout 2 => detected at step 5, NOT at the window
    assert fired == 5
    assert list(mon.dead_ranks()) == [3]
    new = mon.replan(plan)
    assert new.rows_per_rank[3] == 0
    assert new.rows_per_rank.sum() == 8


# --------------------------------------------------------------------------
# integration: checkpoint fault hook + bounded retry
# --------------------------------------------------------------------------


def test_ckpt_fault_hook_transient_then_clears():
    eng = _engine([chaos.ckpt_io_fail(step=3, fails=2)])
    hook = eng.ckpt_fault_hook()
    for _ in range(2):
        with pytest.raises(OSError, match="ckpt_io_fail"):
            hook(3, "/tmp/x")
    hook(3, "/tmp/x")                     # third attempt passes
    hook(5, "/tmp/x")                     # other steps never fault


def test_ckpt_fault_hook_persistent_and_wildcard_step():
    hook = _engine([chaos.ckpt_io_fail(step=None, mode="persistent")
                    ]).ckpt_fault_hook()
    for step in (1, 2, 9):
        for _ in range(4):
            with pytest.raises(OSError, match="persistent"):
                hook(step, "/tmp/x")


def test_checkpoint_manager_retries_transient_io_and_commits(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    eng = _engine([chaos.ckpt_io_fail(step=None, fails=2)])
    mgr = CheckpointManager(str(tmp_path), io_retries=3,
                            io_backoff_s=0.001,
                            fault_hook=eng.ckpt_fault_hook())
    state = {"w": np.arange(4, dtype=np.float32)}
    mgr.save(1, state, block=True)        # 2 injected failures, 3rd OK
    assert mgr.all_steps() == [1]
    restored, meta = mgr.restore(state)
    np.testing.assert_array_equal(restored["w"], state["w"])
    assert meta["step"] == 1


def test_checkpoint_manager_reraises_after_retry_budget(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    eng = _engine([chaos.ckpt_io_fail(step=None, mode="persistent")])
    mgr = CheckpointManager(str(tmp_path), io_retries=3,
                            io_backoff_s=0.001,
                            fault_hook=eng.ckpt_fault_hook())
    with pytest.raises(OSError, match="persistent"):
        mgr.save(1, {"w": np.zeros(2, np.float32)}, block=True)
    assert mgr.all_steps() == []          # nothing half-committed


# --------------------------------------------------------------------------
# after_remesh: surviving-topology renumbering
# --------------------------------------------------------------------------


def test_after_remesh_remaps_ranks_and_keeps_global_faults():
    eng = _engine([chaos.slowdown(2, 3.0),          # pod 1 -> survives
                   chaos.flaky(0, 0.5),             # pod 0 -> dropped
                   chaos.kill(pod=0, step=5),       # dead pod -> dropped
                   chaos.ckpt_io_fail(step=None)],  # global -> kept
                  speeds=[1.0, 1.0, 2.0, 4.0])
    new = eng.after_remesh(alive_pods=[1])
    assert new.num_ranks == 2 and new.pods == 1
    kinds = sorted(e.kind for e in new.schedule.events)
    assert kinds == ["ckpt_io_fail", "slowdown"]
    slow = [e for e in new.schedule.events if e.kind == "slowdown"][0]
    assert slow.rank == 0                 # old rank 2 -> new rank 0
    np.testing.assert_allclose(new.speeds, [2.0, 4.0])
    assert new.schedule.seed == eng.schedule.seed


def test_after_remesh_renumbers_surviving_pod_faults():
    eng = chaos.ChaosEngine(chaos.ChaosSchedule(
        events=(chaos.kill(pod=2, step=9),)), num_ranks=6,
        data_per_pod=2)
    new = eng.after_remesh(alive_pods=[0, 2])
    (ev,) = new.schedule.events
    assert ev.pod == 1                    # old pod 2 -> new pod 1
    assert new.killed(9, 2) and new.killed(9, 3)
    assert not new.killed(9, 0)
