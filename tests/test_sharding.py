"""Sharding-rule unit tests (AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import base as cfgbase
from repro.launch import sharding as shr
from repro.models import transformer as tr

MESH = compat.abstract_mesh((16, 16), ("data", "model"))
MESH3 = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_fit_spec_drops_nondivisible():
    assert shr.fit_spec((2, 128), P("model", None), MESH) == P()
    assert shr.fit_spec((32, 128), P("model", "data"), MESH) == \
        P("model", "data")
    assert shr.fit_spec((32, 100), P("model", "data"), MESH) == P("model")
    # tuple axes: 32 % (2*16) == 0 on the 3-axis mesh
    assert shr.fit_spec((32, 8), P(("pod", "data"), None), MESH3) == \
        P(("pod", "data"))
    assert shr.fit_spec((30, 8), P(("pod", "data"), None), MESH3) == P()


@pytest.mark.parametrize("arch", cfgbase.list_archs())
def test_param_specs_cover_all_leaves(arch):
    """Every full-config param leaf gets a spec that divides its dims."""
    cfg = cfgbase.resolve(arch)
    shapes = jax.eval_shape(lambda k: tr.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = shr.param_specs(cfg, shapes, MESH3)
    n_sharded = 0
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        assert isinstance(spec, P), path
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= MESH3.shape[a]
            assert dim % size == 0, (path, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", cfgbase.list_archs())
def test_big_leaves_are_sharded(arch):
    """No parameter leaf > 64 MB may stay fully replicated (memory!)."""
    cfg = cfgbase.resolve(arch)
    shapes = jax.eval_shape(lambda k: tr.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = shr.param_specs(cfg, shapes, MESH3)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        n_bytes = leaf.size * 4
        if n_bytes > 64e6:
            assert any(ax is not None for ax in spec), \
                f"{arch}: {path} ({n_bytes / 1e6:.0f} MB) replicated"


def test_cache_specs_split_k_for_small_kv():
    """glm4 (kv=2 < model=16): cache must shard sequence, not heads."""
    cfg = cfgbase.resolve("glm4-9b")
    from repro.models.model import build_model
    m = build_model(cfg)
    cache = jax.eval_shape(lambda: m.init_cache(128, 32768))
    specs = shr.cache_specs(cfg, cache, MESH, batch=128)
    k_spec = specs["k"]
    # (L, B, S, Hkv, Dh): S over model (index 2)
    assert k_spec[2] == "model", k_spec
    # deepseek MLA latent: split-K over S too
    cfg2 = cfgbase.resolve("deepseek-v2-236b")
    m2 = build_model(cfg2)
    cache2 = jax.eval_shape(lambda: m2.init_cache(128, 32768))
    specs2 = shr.cache_specs(cfg2, cache2, MESH, batch=128)
    assert specs2["c_kv"][2] == "model"


def test_batch_specs_handle_unshardable_batch():
    cfg = cfgbase.resolve("zamba2-2.7b")
    # long_500k: global_batch=1 cannot shard over dp
    specs = shr.batch_specs(cfg, MESH, global_rows=1)
    assert specs["labels"] == P(None, None)
    specs2 = shr.batch_specs(cfg, MESH, global_rows=256)
    assert specs2["labels"][0] in ("data", ("data",))
