"""`hypothesis` with a deterministic fallback when it is not installed.

The container may not ship hypothesis; rather than erroring at
collection (which takes the whole tier-1 suite down), property tests
import ``given / settings / st`` from here. When hypothesis is absent a
minimal shim runs each property against a fixed number of
deterministically sampled examples — far weaker than real hypothesis
(no shrinking, no database), but the invariants still get exercised.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def filter(self, pred):
            def draw(rnd):
                for _ in range(1000):
                    v = self._draw(rnd)
                    if pred(v):
                        return v
                raise ValueError("fallback strategy filter too strict")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._draw(rnd)))

        def example_from(self, rnd):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rnd: [elements.example_from(rnd)
                             for _ in range(rnd.randint(min_size,
                                                        max_size))])

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rnd: rnd.choice(items))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

    st = _Strategies()

    def given(**strategies):
        def deco(f):
            def wrapper(*args, **kwargs):
                rnd = random.Random(0)
                n = min(getattr(f, "_max_examples", _FALLBACK_EXAMPLES),
                        25)
                for _ in range(n):
                    drawn = {k: s.example_from(rnd)
                             for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            # pytest must only see the fixture params, not the drawn ones
            sig = inspect.signature(f)
            fixture_params = [p for name, p in sig.parameters.items()
                              if name not in strategies]
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper
        return deco

    def settings(max_examples=_FALLBACK_EXAMPLES, **_):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco
