"""Property-based tests of the HetSeq invariant (the paper's core claim).

For ANY split of a global batch across workers with arbitrary per-worker
capacities (including zero => all-dummy workers), the weighted
aggregation of per-worker losses/gradients equals single-process
training over the union of real rows.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import base as cfgbase
from repro.core import accumulate, capacity, dummy, weighting
from repro.models.model import build_model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(cfgbase.smoke_config("tinyllama-1.1b"),
                              compute_dtype="float32", num_layers=1,
                              d_model=32, num_heads=4, num_kv_heads=2,
                              d_ff=64, vocab_size=64)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    return m, params


def _single_process(m, params, samples):
    g = samples["labels"].shape[0]
    s = samples["labels"].shape[1]
    batch = {"inputs": jnp.asarray(samples["inputs"]),
             "labels": jnp.asarray(samples["labels"]),
             "weights": jnp.ones((g, s))}

    def obj(p, b):
        o, w, _ = m.loss_fn(p, b)
        return o, w

    (o, w), grads = jax.value_and_grad(obj, has_aux=True)(params, batch)
    return (weighting.finalize(o, w),
            weighting.scale_grads(grads, w))


# --------------------------------------------------------------------------
# capacity planner properties
# --------------------------------------------------------------------------


@given(
    rows=st.integers(min_value=1, max_value=200),
    caps=st.lists(st.floats(min_value=0.0, max_value=10.0),
                  min_size=1, max_size=12).filter(lambda c: sum(c) > 0),
)
@settings(max_examples=200, deadline=None)
def test_planner_conserves_rows(rows, caps):
    plan = capacity.plan_capacities(rows, caps)
    assert plan.rows_per_rank.sum() == rows
    assert plan.rows_per_rank.max() <= plan.buffer_rows
    assert (plan.rows_per_rank[np.asarray(caps) == 0] == 0).all()
    w = plan.row_weights()
    assert w.shape == (len(caps), plan.buffer_rows)
    assert w.sum() == rows


@given(
    rows=st.integers(min_value=1, max_value=100),
    n=st.integers(min_value=1, max_value=8),
    headroom=st.floats(min_value=1.0, max_value=2.0),
)
@settings(max_examples=100, deadline=None)
def test_planner_proportionality(rows, n, headroom):
    """Equal capacities => near-equal rows (largest remainder)."""
    plan = capacity.plan_capacities(rows, [1.0] * n, headroom=headroom)
    assert plan.rows_per_rank.max() - plan.rows_per_rank.min() <= 1


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    samples = {"inputs": rng.integers(0, 50, (13, 8)).astype(np.int32),
               "labels": rng.integers(0, 50, (13, 8)).astype(np.int32)}
    plan = capacity.plan_capacities(13, [3, 0, 1, 2])
    packed = dummy.pack_global_batch(samples, plan)
    assert packed["inputs"].shape[0] == plan.padded_rows
    rec = dummy.unpack_real_rows(packed, plan)
    np.testing.assert_array_equal(rec["inputs"], samples["inputs"])
    np.testing.assert_array_equal(rec["labels"], samples["labels"])
    assert rec["weights"].min() == 1.0
    # dummy rows: weight 0 everywhere outside real rows
    assert packed["weights"].sum() == 13 * 8


# --------------------------------------------------------------------------
# the invariant itself (hypothesis over capacity mixes)
# --------------------------------------------------------------------------


@pytest.mark.slow
@given(
    caps=st.lists(st.integers(min_value=0, max_value=4),
                  min_size=2, max_size=5).filter(lambda c: sum(c) > 0),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
@settings(max_examples=20, deadline=None)
def test_hetseq_invariant_random_capacities(small_model, caps, seed):
    m, params = small_model
    rng = np.random.default_rng(seed)
    g, s = 8, 12
    samples = {
        "inputs": rng.integers(0, 64, (g, s)).astype(np.int32),
        "labels": rng.integers(0, 64, (g, s)).astype(np.int32),
    }
    loss_ref, g_ref = _single_process(m, params, samples)

    plan = capacity.plan_capacities(g, [float(c) for c in caps])
    packed = dummy.pack_global_batch(samples, plan)
    b = plan.buffer_rows
    worker_batches = [
        {k: jnp.asarray(packed[k][r * b:(r + 1) * b]) for k in packed}
        for r in range(plan.num_ranks)
    ]
    loss_het, g_het = weighting.simulate_workers(m.loss_fn, params,
                                                 worker_batches)
    assert abs(float(loss_ref) - float(loss_het)) < 1e-5
    for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_het)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   atol=5e-6)


def test_invariant_with_empty_worker(small_model):
    """The paper's empty-batch case: a worker with zero rows still
    aggregates exactly (its dummy batch contributes weight 0)."""
    m, params = small_model
    rng = np.random.default_rng(1)
    samples = {"inputs": rng.integers(0, 64, (5, 10)).astype(np.int32),
               "labels": rng.integers(0, 64, (5, 10)).astype(np.int32)}
    loss_ref, g_ref = _single_process(m, params, samples)
    plan = capacity.plan_capacities(5, [2.0, 2.0, 1.0, 0.0])
    packed = dummy.pack_global_batch(samples, plan)
    b = plan.buffer_rows
    wbs = [{k: jnp.asarray(packed[k][r * b:(r + 1) * b]) for k in packed}
           for r in range(4)]
    assert float(wbs[3]["weights"].sum()) == 0.0       # empty worker
    loss_het, g_het = weighting.simulate_workers(m.loss_fn, params, wbs)
    assert abs(float(loss_ref) - float(loss_het)) < 1e-5
    for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_het)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   atol=5e-6)


@pytest.mark.slow
@given(accum=st.sampled_from([1, 2, 4]),
       seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=10, deadline=None)
def test_accumulation_exactness(small_model, accum, seed):
    """M4: accumulated microbatch grads == one-shot grads, any weights."""
    m, params = small_model
    rng = np.random.default_rng(seed)
    g, s = 8, 12
    samples = {"inputs": rng.integers(0, 64, (g, s)).astype(np.int32),
               "labels": rng.integers(0, 64, (g, s)).astype(np.int32)}
    loss_ref, g_ref = _single_process(m, params, samples)
    batch = {"inputs": jnp.asarray(samples["inputs"]),
             "labels": jnp.asarray(samples["labels"]),
             "weights": jnp.ones((g, s))}
    mbs = accumulate.split_microbatches(batch, accum, num_ranks=2)
    g_acc, loss_acc, w = accumulate.accumulate_grads(m.loss_fn, params,
                                                     mbs)
    assert abs(float(loss_ref) - float(loss_acc)) < 1e-5
    for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   atol=5e-6)


def test_partial_final_batch_epoch_boundary(small_model):
    """Paper's motivating example: 5 rows, 4 workers, batch 2 => worker
    loads 2/2/1/0 with the half-filled and empty buffers weighted."""
    m, params = small_model
    rng = np.random.default_rng(3)
    samples = {"inputs": rng.integers(0, 64, (5, 10)).astype(np.int32),
               "labels": rng.integers(0, 64, (5, 10)).astype(np.int32)}
    plan = capacity.plan_capacities(5, [1, 1, 1, 1], buffer_rows=2)
    # the paper's greedy packing gives 2/2/1/0; largest-remainder gives
    # the better-balanced 2/1/1/1 — both are exact, the invariant is
    # what matters
    assert plan.rows_per_rank.sum() == 5
    assert plan.rows_per_rank.max() <= 2
    packed = dummy.pack_global_batch(samples, plan)
    loss_ref, _ = _single_process(m, params, samples)
    wbs = [{k: jnp.asarray(packed[k][r * 2:(r + 1) * 2]) for k in packed}
           for r in range(4)]
    loss_het, _ = weighting.simulate_workers(m.loss_fn, params, wbs)
    assert abs(float(loss_ref) - float(loss_het)) < 1e-5
