"""weighting="canonical" end to end: config gating, the sampler's
plan-independent canonical row layout, and the headline guarantee —
bit-identical training trajectories across capacity replans."""
import subprocess
import sys
import os
import textwrap

import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.configs.base import HetConfig
from repro.core import capacity
from repro.data import sampler, synthetic
from repro.data.dataset import ShardedDataset

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_canonical_config_gating():
    """The order-canonical sum must be the ONLY reduction: every engine
    that regroups it (buckets, hierarchy, compression, accumulation) is
    rejected at validate() time with an actionable message."""
    HetConfig(weighting="canonical").validate()    # plain allreduce: ok
    bad = [HetConfig(weighting="canonical", grad_reduction="hierarchical"),
           HetConfig(weighting="canonical",
                     grad_reduction="bucketed_allreduce", bucket_mb=4.0),
           HetConfig(weighting="canonical", compression="int8"),
           HetConfig(weighting="canonical", overlap="buckets",
                     grad_reduction="bucketed_allreduce", bucket_mb=4.0),
           HetConfig(weighting="canonical", accum_steps=2)]
    for het in bad:
        with pytest.raises(ValueError, match="canonical"):
            het.validate()
    assert "canonical" in cfgbase.WEIGHTING_MODES


def test_canonical_pack_is_plan_independent(tmp_path):
    """Same epoch, same batch index => byte-identical canonical batches
    under different capacity plans, with partial tails padded by
    trailing weight-0 rows (never interleaved)."""
    corpus = synthetic.build_synthetic_corpus(
        str(tmp_path / "c"), num_seqs=20, seq_len=16, vocab=64,
        rows_per_shard=8, seed=0)
    ds = ShardedDataset(corpus)
    plan_a = capacity.plan_capacities(6, [2, 1])
    plan_b = capacity.plan_capacities(6, [1, 3])
    smp_a = sampler.HetSampler(ds, plan_a, seed=3, canonical_order=True)
    smp_b = sampler.HetSampler(ds, plan_b, seed=3, canonical_order=True)
    batches_a = list(smp_a.iter_epoch(0))
    batches_b = list(smp_b.iter_epoch(0))
    assert len(batches_a) == len(batches_b) == 4     # 6+6+6+2
    for ba, bb in zip(batches_a, batches_b):
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])
        assert ba["inputs"].shape[0] == 6            # static shape
    tail = batches_a[-1]["weights"]
    assert np.all(tail[:2] > 0) and np.all(tail[2:] == 0)
    # the SPMD layout, by contrast, IS plan-dependent: rank buffers
    smp_r = sampler.HetSampler(ds, plan_a, seed=3)
    rows_spmd = next(iter(smp_r))["inputs"].shape[0]
    assert rows_spmd == plan_a.padded_rows != 6 or rows_spmd != 6


@pytest.mark.slow
def test_canonical_bit_identity_across_replans():
    """The wired train step (launch/steps.py canonical path + the
    sampler's canonical layout): a run that replans mid-stream — rows
    shifting between DP ranks — produces the bit-identical per-step
    loss sequence and final params as a run under a fixed plan, on the
    same global row stream. fp32 sums are not associative, so this
    only holds because the aggregation is order-canonical."""
    out = run_child("""
        import dataclasses, tempfile
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity
        from repro.data import sampler, synthetic
        from repro.data.dataset import ShardedDataset

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        corpus = synthetic.build_synthetic_corpus(
            tempfile.mkdtemp() + "/c", num_seqs=20, seq_len=16,
            vocab=cfg.vocab_size, rows_per_shard=8, seed=0)
        ds = ShardedDataset(corpus)
        shape = ShapeConfig("t", 16, 6, "train")
        tcfg = TrainConfig(
            model=cfg, shape=shape,
            het=HetConfig(weighting="canonical").validate(),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))

        def run(plans):           # plans: one CapacityPlan per step
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            smp = sampler.HetSampler(ds, plans[0], seed=3,
                                     canonical_order=True)
            entries = smp.epoch_batches(0)
            losses, state = [], None
            with compat.set_mesh(mesh):
                state = steps.init_train_state(m, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(m, tcfg, mesh)
                for i, entry in enumerate(entries):
                    smp.set_plan(plans[i])
                    batch = {k: jnp.asarray(v)
                             for k, v in smp.pack(entry).items()}
                    state, met = step(state, batch)
                    losses.append(np.asarray(met["loss"]))
                params = jax.device_get(state.params)
            return losses, params

        fixed = capacity.plan_capacities(6, [2, 1])
        la, pa = run([fixed] * 4)
        lb, pb = run([capacity.plan_capacities(6, [1, 1])] * 2 +
                     [capacity.plan_capacities(6, [3, 1])] * 2)
        for i, (x, y) in enumerate(zip(la, lb)):
            assert x.tobytes() == y.tobytes(), (i, x, y)
        mism = [k for k, (u, v) in enumerate(zip(
                    jax.tree.leaves(pa), jax.tree.leaves(pb)))
                if np.asarray(u).tobytes() != np.asarray(v).tobytes()]
        assert not mism, f"params differ at leaves {mism}"
        print("losses", [float(x) for x in la])
        print("OK")
        """)
    assert "OK" in out
