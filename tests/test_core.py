"""Core modules: compression/error feedback, straggler, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import capacity, compression, elastic, straggler


# --------------------------------------------------------------------------
# compression + error feedback
# --------------------------------------------------------------------------


def test_error_feedback_accumulates_what_quantization_loses():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (512,)) * 2}
    err = compression.init_error_state(g)
    (q, s), err2 = compression.compress_tree(g, err)
    deq = compression.decompress_tree(q, s, g)
    # error state == exactly the quantization residual
    np.testing.assert_allclose(
        np.asarray(err2["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_error_feedback_converges_sgd():
    """Compressed-SGD with error feedback tracks exact SGD on a convex
    problem; without it the bias is visibly worse."""
    target = jax.random.normal(jax.random.PRNGKey(1), (256,))

    def run(error_feedback):
        x = jnp.zeros((256,))
        err = jnp.zeros((256,))
        for i in range(150):
            g = x - target
            corrected = g + (err if error_feedback else 0.0)
            from repro.kernels.quantize import ref as q_ref
            q, s = q_ref.quantize_int8(corrected * 64, block_size=256)
            deq = q_ref.dequantize_int8(q, s, corrected.shape, 256) / 64
            if error_feedback:
                err = corrected - deq
            x = x - 0.1 * deq
        return float(jnp.linalg.norm(x - target))

    assert run(True) < 1e-2
    assert run(True) <= run(False) + 1e-6


def test_compression_ratio():
    g = {"a": jnp.zeros((1024, 1024))}
    r = compression.compression_ratio(g, block_size=256)
    assert 0.25 < r < 0.27          # int8 + fp32 scale per 256 block


# --------------------------------------------------------------------------
# straggler monitor
# --------------------------------------------------------------------------


def test_straggler_shifts_load_to_fast_ranks():
    mon = straggler.StragglerMonitor(num_ranks=3, replan_interval=1)
    plan = capacity.homogeneous_plan(30, 3, headroom=1.5)
    for _ in range(5):
        mon.observe([1.0, 2.0, 4.0])
    new = mon.replan(plan)
    assert new.rows_per_rank[0] > new.rows_per_rank[1] > \
        new.rows_per_rank[2]
    assert new.rows_per_rank.sum() == 30


def test_dead_rank_detection_and_escalation():
    mon = straggler.StragglerMonitor(num_ranks=2, replan_interval=1,
                                     dead_timeout_steps=2)
    plan = capacity.homogeneous_plan(8, 2)        # no headroom
    mon.observe([1.0, None])
    assert len(mon.dead_ranks()) == 0
    mon.observe([1.0, None])
    assert list(mon.dead_ranks()) == [1]
    with pytest.raises(straggler.RemeshRequired):
        mon.replan(plan)
    # with headroom the same failure is absorbed without a remesh
    plan_h = capacity.homogeneous_plan(8, 2, headroom=2.0)
    new = mon.replan(plan_h)
    assert new.rows_per_rank.tolist() == [8, 0]


@given(times=st.lists(st.floats(min_value=0.1, max_value=10.0),
                      min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_replan_conserves_global_batch(times):
    n = len(times)
    mon = straggler.StragglerMonitor(num_ranks=n, replan_interval=1)
    plan = capacity.homogeneous_plan(4 * n, n, headroom=4.0)
    for _ in range(3):
        mon.observe(times)
    new = mon.replan(plan)
    assert new.rows_per_rank.sum() == 4 * n
    assert new.buffer_rows == plan.buffer_rows    # no shape change


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------


def test_remesh_noop_when_all_alive():
    topo = elastic.MeshTopology(pods=2, data_per_pod=4, model=2)
    d = elastic.plan_remesh(topo, alive_pods=[0, 1], global_rows=64)
    assert not d.restart_required
    assert d.plan.global_rows == 64


def test_remesh_on_pod_loss_keeps_global_batch():
    topo = elastic.MeshTopology(pods=2, data_per_pod=4, model=2)
    d = elastic.plan_remesh(topo, alive_pods=[1], global_rows=64)
    assert d.restart_required
    assert d.topology.mesh_shape() == (4, 2)
    assert d.plan.global_rows == 64               # exact resume invariant
    assert d.plan.rows_per_rank.sum() == 64
    assert elastic.validate_resume_equivalence(d.plan, d.plan)


def test_remesh_heterogeneous_pod_capacities():
    topo = elastic.MeshTopology(pods=3, data_per_pod=2, model=1)
    d = elastic.plan_remesh(topo, alive_pods=[0, 2], global_rows=30,
                            capacities_per_pod=[2.0, 1.0, 1.0])
    assert d.restart_required
    # surviving pods 0 (cap 2) and 2 (cap 1): pod 0 ranks get ~2x rows
    rows = d.plan.rows_per_rank
    assert rows[:2].sum() > rows[2:].sum()
    assert rows.sum() == 30
