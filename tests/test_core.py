"""Core modules: compression/error feedback, accumulation, straggler,
elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import accumulate, capacity, compression, elastic, straggler


# --------------------------------------------------------------------------
# compression + error feedback
# --------------------------------------------------------------------------


def test_error_feedback_accumulates_what_quantization_loses():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (512,)) * 2}
    err = compression.init_error_state(g)
    (q, s), err2 = compression.compress_tree(g, err)
    deq = compression.decompress_tree(q, s, g)
    # error state == exactly the quantization residual
    np.testing.assert_allclose(
        np.asarray(err2["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_error_feedback_converges_sgd():
    """Compressed-SGD with error feedback tracks exact SGD on a convex
    problem; without it the bias is visibly worse."""
    target = jax.random.normal(jax.random.PRNGKey(1), (256,))

    def run(error_feedback):
        x = jnp.zeros((256,))
        err = jnp.zeros((256,))
        for i in range(150):
            g = x - target
            corrected = g + (err if error_feedback else 0.0)
            from repro.kernels.quantize import ref as q_ref
            q, s = q_ref.quantize_int8(corrected * 64, block_size=256)
            deq = q_ref.dequantize_int8(q, s, corrected.shape, 256) / 64
            if error_feedback:
                err = corrected - deq
            x = x - 0.1 * deq
        return float(jnp.linalg.norm(x - target))

    assert run(True) < 1e-2
    assert run(True) <= run(False) + 1e-6


def test_compression_ratio():
    g = {"a": jnp.zeros((1024, 1024))}
    r = compression.compression_ratio(g, block_size=256)
    assert 0.25 < r < 0.27          # int8 + fp32 scale per 256 block


# --------------------------------------------------------------------------
# accumulation scan core
# --------------------------------------------------------------------------


def test_split_microbatches_error_cases():
    batch = {"x": jnp.zeros((12, 4))}
    # 12 rows: accum=5 never divides
    with pytest.raises(ValueError, match="not divisible"):
        accumulate.split_microbatches(batch, accum_steps=5)
    # divisible by accum alone but not by accum x ranks
    with pytest.raises(ValueError, match="not divisible"):
        accumulate.split_microbatches(batch, accum_steps=4, num_ranks=5)
    # valid split preserves shape bookkeeping
    mbs = accumulate.split_microbatches(batch, accum_steps=3, num_ranks=2)
    assert mbs["x"].shape == (3, 4, 4)


def test_split_microbatches_rank_locality():
    """Every microbatch must take an equal slice of EVERY rank's rows."""
    rows = jnp.arange(8)[:, None] * jnp.ones((1, 2))
    mbs = accumulate.split_microbatches({"x": rows}, accum_steps=2,
                                        num_ranks=2)
    # rank 0 owns rows 0-3, rank 1 rows 4-7; microbatch 0 must hold the
    # first half of each rank's buffer
    np.testing.assert_array_equal(
        np.asarray(mbs["x"][0, :, 0]), [0, 1, 4, 5])
    np.testing.assert_array_equal(
        np.asarray(mbs["x"][1, :, 0]), [2, 3, 6, 7])


def test_scan_accumulate_matches_direct_sum():
    """The shared scan core returns unscaled sums identical to a loop."""
    params = {"w": jnp.array([1.0, -2.0, 0.5])}
    mbs = {"x": jnp.arange(12.0).reshape(3, 4)}

    def obj(p, mb):
        o = (p["w"].sum() * mb["x"]).sum()
        return o, jnp.float32(mb["x"].size)

    grad_fn = jax.value_and_grad(obj, has_aux=True)
    g, o, w = accumulate.scan_accumulate(grad_fn, params, mbs)
    assert float(w) == 12.0
    ref_o = sum(float(obj(params, {"x": mbs["x"][i]})[0]) for i in range(3))
    assert abs(float(o) - ref_o) < 1e-5
    np.testing.assert_allclose(np.asarray(g["w"]),
                               np.full((3,), float(mbs["x"].sum())),
                               rtol=1e-6)


def test_scan_accumulate_carry_dtype_policy():
    params = {"a": jnp.zeros((2,), jnp.bfloat16),
              "b": jnp.zeros((2,), jnp.float32)}
    mbs = {"x": jnp.ones((2, 2))}

    def obj(p, mb):
        o = ((p["a"].astype(jnp.float32) + p["b"]) * mb["x"]).sum()
        return o, jnp.float32(1.0)

    grad_fn = jax.value_and_grad(obj, has_aux=True)

    def carry_dtype(p):
        return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

    g, _, _ = accumulate.scan_accumulate(grad_fn, params, mbs,
                                         carry_dtype=carry_dtype)
    assert g["a"].dtype == jnp.bfloat16
    assert g["b"].dtype == jnp.float32


# --------------------------------------------------------------------------
# straggler monitor
# --------------------------------------------------------------------------


def test_straggler_shifts_load_to_fast_ranks():
    mon = straggler.StragglerMonitor(num_ranks=3, replan_interval=1)
    plan = capacity.homogeneous_plan(30, 3, headroom=1.5)
    for _ in range(5):
        mon.observe([1.0, 2.0, 4.0])
    new = mon.replan(plan)
    assert new.rows_per_rank[0] > new.rows_per_rank[1] > \
        new.rows_per_rank[2]
    assert new.rows_per_rank.sum() == 30


def test_dead_rank_detection_and_escalation():
    mon = straggler.StragglerMonitor(num_ranks=2, replan_interval=1,
                                     dead_timeout_steps=2)
    plan = capacity.homogeneous_plan(8, 2)        # no headroom
    mon.observe([1.0, None])
    assert len(mon.dead_ranks()) == 0
    mon.observe([1.0, None])
    assert list(mon.dead_ranks()) == [1]
    with pytest.raises(straggler.RemeshRequired):
        mon.replan(plan)
    # with headroom the same failure is absorbed without a remesh
    plan_h = capacity.homogeneous_plan(8, 2, headroom=2.0)
    new = mon.replan(plan_h)
    assert new.rows_per_rank.tolist() == [8, 0]


def test_immediate_replan_on_newly_dead_rank():
    """A rank dying right after a window boundary must trigger a replan
    NOW, not ``replan_interval`` steps later — and once handled, the
    same dead rank must not keep re-triggering every step."""
    mon = straggler.StragglerMonitor(num_ranks=3, replan_interval=100,
                                     dead_timeout_steps=2)
    plan = capacity.homogeneous_plan(6, 3, headroom=2.0)
    mon.observe([1.0, 1.0, 1.0])
    assert not mon.should_replan()
    mon.observe([1.0, 1.0, None])
    assert not mon.should_replan()        # one miss is not dead yet
    mon.observe([1.0, 1.0, None])
    assert mon.should_replan()            # dead: immediate, mid-window
    new = mon.replan(plan)
    assert new.rows_per_rank[2] == 0
    # handled: the still-dead rank must not re-fire off-window
    mon.observe([1.0, 1.0, None])
    assert not mon.should_replan()
    # ... but a SECOND death re-triggers immediately
    mon.observe([1.0, None, None])
    mon.observe([1.0, None, None])
    assert mon.should_replan()
    assert sorted(mon.dead_ranks().tolist()) == [1, 2]


def test_remesh_required_escalation_chains_planner_error():
    """The RemeshRequired raised when survivors cannot fit the global
    batch carries the planner's ValueError as its cause."""
    mon = straggler.StragglerMonitor(num_ranks=2, replan_interval=1,
                                     dead_timeout_steps=1)
    plan = capacity.homogeneous_plan(8, 2)        # buffer 4, no headroom
    mon.observe([1.0, None])                      # rank 1 dead instantly
    with pytest.raises(straggler.RemeshRequired) as ei:
        mon.replan(plan)
    assert isinstance(ei.value.__cause__, ValueError)


def test_monitor_recreated_after_remesh_matches_new_mesh():
    """Regression for the re-mesh handoff: the old monitor rejects the
    new mesh's step-time width loudly, and a monitor/plan rebuilt from
    the RemeshDecision line up with the surviving topology."""
    topo = elastic.MeshTopology(pods=2, data_per_pod=2, model=1)
    d = elastic.plan_remesh(topo, alive_pods=[0], global_rows=8)
    assert d.restart_required
    assert len(d.plan.rows_per_rank) == d.topology.dp_size == 2

    old = straggler.StragglerMonitor(num_ranks=topo.dp_size)
    with pytest.raises(ValueError, match="re-mesh"):
        old.observe([1.0] * d.topology.dp_size)   # stale width: loud

    fresh = straggler.StragglerMonitor(num_ranks=d.topology.dp_size,
                                       replan_interval=1)
    fresh.observe([1.0, 2.0])
    new = fresh.replan(d.plan)
    assert len(new.rows_per_rank) == d.topology.dp_size
    assert new.rows_per_rank.sum() == 8


@given(times=st.lists(st.floats(min_value=0.1, max_value=10.0),
                      min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_replan_conserves_global_batch(times):
    n = len(times)
    mon = straggler.StragglerMonitor(num_ranks=n, replan_interval=1)
    plan = capacity.homogeneous_plan(4 * n, n, headroom=4.0)
    for _ in range(3):
        mon.observe(times)
    new = mon.replan(plan)
    assert new.rows_per_rank.sum() == 4 * n
    assert new.buffer_rows == plan.buffer_rows    # no shape change


def test_replan_from_step_times_all_dead_but_one():
    """inf is the sanctioned dead-rank marker: with every rank but one
    dead, the survivor inherits the whole global batch."""
    plan = capacity.homogeneous_plan(12, 3, headroom=4.0)
    new = capacity.replan_from_step_times(
        plan, np.array([np.inf, 2.0, np.inf]))
    assert new.rows_per_rank.tolist() == [0, 12, 0]
    assert new.global_rows == plan.global_rows
    # all dead is unplannable, not silently zero-rowed
    with pytest.raises(ValueError, match="all ranks dead"):
        capacity.replan_from_step_times(
            plan, np.array([np.inf, np.inf, np.inf]))


def test_replan_from_step_times_rejects_garbage_measurements():
    """A zero/negative/NaN step time is a broken monitor, not a fast
    rank — it must raise loudly NAMING the offending ranks, never
    silently starve a healthy one."""
    plan = capacity.homogeneous_plan(12, 3)
    for bad, offenders in (([1.0, 0.0, 2.0], [1]),
                           ([-0.5, 1.0, 2.0], [0]),
                           ([1.0, np.nan, -1.0], [1, 2])):
        with pytest.raises(ValueError, match="must be positive") as ei:
            capacity.replan_from_step_times(plan, np.asarray(bad))
        for r in offenders:
            assert f"{offenders}" in str(ei.value)
    # shape mismatch is its own loud error
    with pytest.raises(ValueError, match="shape"):
        capacity.replan_from_step_times(plan, np.ones(4))


def test_replan_after_plan_record_roundtrip():
    """plan -> plan_record -> plan_from_record is bit-faithful and the
    round-tripped plan replans identically to the original (the
    checkpoint-resume path feeds replan exactly this way)."""
    import json
    plan = capacity.plan_capacities(30, [4.0, 2.0, 1.0], headroom=1.5)
    back = capacity.plan_from_record(
        json.loads(json.dumps(capacity.plan_record(plan))))
    np.testing.assert_array_equal(back.rows_per_rank,
                                  plan.rows_per_rank)
    np.testing.assert_array_equal(back.capacities, plan.capacities)
    assert (back.buffer_rows, back.global_rows) == \
        (plan.buffer_rows, plan.global_rows)
    ema = np.array([1.0, 3.0, np.inf])
    a = capacity.replan_from_step_times(plan, ema)
    b = capacity.replan_from_step_times(back, ema)
    np.testing.assert_array_equal(a.rows_per_rank, b.rows_per_rank)
    assert a.rows_per_rank[2] == 0                # dead rank drained


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------


def test_remesh_noop_when_all_alive():
    topo = elastic.MeshTopology(pods=2, data_per_pod=4, model=2)
    d = elastic.plan_remesh(topo, alive_pods=[0, 1], global_rows=64)
    assert not d.restart_required
    assert d.plan.global_rows == 64


def test_remesh_on_pod_loss_keeps_global_batch():
    topo = elastic.MeshTopology(pods=2, data_per_pod=4, model=2)
    d = elastic.plan_remesh(topo, alive_pods=[1], global_rows=64)
    assert d.restart_required
    assert d.topology.mesh_shape() == (4, 2)
    assert d.plan.global_rows == 64               # exact resume invariant
    assert d.plan.rows_per_rank.sum() == 64
    assert elastic.validate_resume_equivalence(d.plan, d.plan)


def test_remesh_heterogeneous_pod_capacities():
    topo = elastic.MeshTopology(pods=3, data_per_pod=2, model=1)
    d = elastic.plan_remesh(topo, alive_pods=[0, 2], global_rows=30,
                            capacities_per_pod=[2.0, 1.0, 1.0])
    assert d.restart_required
    # surviving pods 0 (cap 2) and 2 (cap 1): pod 0 ranks get ~2x rows
    rows = d.plan.rows_per_rank
    assert rows[:2].sum() > rows[2:].sum()
    assert rows.sum() == 30
