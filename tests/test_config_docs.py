"""Docs-drift guards: the README config matrix must match the code.

The README documents (a) the accepted values of every ``HetConfig``
mode knob and (b) the valid ``grad_reduction`` x ``overlap`` grid with
each cell's requirements. Both tables are parsed here and checked
against the actual validation behavior (``configs/base.py`` constants,
``HetConfig.validate``, ``launch/steps.py::validate_train_config``) so
a code change that isn't reflected in the docs — or a documented combo
the code rejects — fails CI. The quickstart flags are checked against
the train driver's argparse, and the checkpoint overlap-mode bugfix
(restore logs instead of silently adapting) is covered at the end.
"""
import dataclasses
import logging
import os
import re
import sys

import jax
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.configs.base import HetConfig, TrainConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")
if REPO not in sys.path:                      # for benchmarks.docs_smoke
    sys.path.insert(0, REPO)


def _tables(text):
    """All pipe tables as lists of row-cell lists (header first)."""
    tables, current = [], []
    for line in text.splitlines():
        if line.strip().startswith("|"):
            cells = [c.strip() for c in line.strip().strip("|").split("|")]
            if all(set(c) <= set("-: ") for c in cells):
                continue                      # separator row
            current.append(cells)
        elif current:
            tables.append(current)
            current = []
    if current:
        tables.append(current)
    return tables


@pytest.fixture(scope="module")
def readme_tables():
    with open(README) as fh:
        return _tables(fh.read())


def _find_table(tables, *header_needles):
    for t in tables:
        header = " ".join(t[0]).lower()
        if all(n in header for n in header_needles):
            return t
    raise AssertionError(
        f"README table with header containing {header_needles} not "
        f"found")


def test_readme_knob_values_match_constants(readme_tables):
    """The knob/values table lists EXACTLY the accepted mode values."""
    table = _find_table(readme_tables, "knob", "values")
    documented = {}
    for row in table[1:]:
        knob = row[0].strip("`")
        documented[knob] = [v.strip(" `") for v in row[1].split(",")]
    expected = {
        "grad_reduction": list(cfgs.GRAD_REDUCTION_MODES),
        "overlap": list(cfgs.OVERLAP_MODES),
        "compression": list(cfgs.COMPRESSION_MODES),
        "quantize_impl": list(cfgs.QUANTIZE_IMPLS),
        "weighting": list(cfgs.WEIGHTING_MODES),
        "pipeline_schedule": list(cfgs.PIPELINE_MODES),
        "attention_impl": list(cfgs.ATTENTION_IMPLS),
    }
    assert documented == expected, (
        f"README knob table out of sync with configs/base.py:\n"
        f"documented={documented}\nexpected={expected}")


def _combo_config(reduction, overlap, requirements):
    """Build (model_cfg, het) honoring a matrix row's requirements."""
    model = cfgs.smoke_config("olmo-1b")
    kwargs = {"grad_reduction": reduction, "overlap": overlap}
    if "bucket_mb" in requirements:
        kwargs["bucket_mb"] = 0.05
    if "scan_layers" in requirements:
        model = dataclasses.replace(model, scan_layers=False)
    return model, HetConfig(**kwargs)


def test_readme_matrix_rows_match_validation(readme_tables):
    """Every documented (grad_reduction, overlap) cell behaves as its
    'status' column claims — and the grid covers the full product."""
    from repro.launch.steps import validate_train_config
    from repro.models.model import build_model

    table = _find_table(readme_tables, "grad_reduction", "overlap",
                        "status")
    flat_mesh = jax.make_mesh((1, 1), ("data", "model"))
    pod_mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    seen = set()
    for row in table[1:]:
        reduction = row[0].strip("`")
        overlap = row[1].strip("`")
        requirements, status = row[2], row[3]
        assert reduction in cfgs.GRAD_REDUCTION_MODES, row
        assert overlap in cfgs.OVERLAP_MODES, row
        assert status in ("supported", "rejected"), row
        seen.add((reduction, overlap))
        # hierarchical reduces over the pod axis — its checks are only
        # live on a multi-pod-shaped mesh
        mesh = pod_mesh if reduction == "hierarchical" else flat_mesh
        model_cfg, het = _combo_config(reduction, overlap, requirements)
        model = build_model(model_cfg)
        tcfg = TrainConfig(model=model_cfg, het=het)
        if status == "supported":
            validate_train_config(model, tcfg, mesh)
            # each named requirement is real: dropping it must raise
            if "bucket_mb" in requirements:
                bad = dataclasses.replace(het, bucket_mb=0.0)
                with pytest.raises(ValueError, match="bucket_mb"):
                    validate_train_config(
                        model, TrainConfig(model=model_cfg, het=bad),
                        mesh)
            if "scan_layers" in requirements:
                scanned_cfg = dataclasses.replace(model_cfg,
                                                  scan_layers=True)
                scanned = build_model(scanned_cfg)
                with pytest.raises(ValueError, match="scan_layers"):
                    validate_train_config(
                        scanned,
                        TrainConfig(model=scanned_cfg, het=het), mesh)
        else:
            with pytest.raises(ValueError):
                validate_train_config(model, tcfg, mesh)
    full_grid = {(r, o) for r in cfgs.GRAD_REDUCTION_MODES
                 for o in cfgs.OVERLAP_MODES}
    assert seen == full_grid, (
        f"README matrix missing combos: {sorted(full_grid - seen)}")


def test_invalid_mode_values_raise():
    """Unknown values of every mode knob fail HetConfig.validate with
    a message naming the field."""
    for field, good in (("weighting", "tokens"),
                        ("grad_reduction", "allreduce"),
                        ("compression", "none"),
                        ("quantize_impl", "reference"),
                        ("overlap", "none")):
        with pytest.raises(ValueError, match=field):
            HetConfig(**{field: "bogus"}).validate()
    for field, bad, match in ((("bucket_mb"), -1.0, "bucket_mb"),
                              (("accum_steps"), 0, "accum_steps"),
                              (("straggler_ema"), 1.5, "straggler_ema"),
                              (("replan_interval"), 0,
                               "replan_interval"),
                              (("capacities"), (1.0, -2.0),
                               "capacities")):
        with pytest.raises(ValueError, match=match):
            HetConfig(**{field: bad}).validate()


def test_readme_chaos_presets_match_registry(readme_tables):
    """The README chaos-preset table lists EXACTLY the registered
    presets, and each row's fault kinds match what the preset builder
    actually schedules."""
    from repro.core import chaos

    table = _find_table(readme_tables, "preset", "faults")
    documented = {}
    for row in table[1:]:
        name = row[0].strip("`")
        documented[name] = {k.strip(" `") for k in row[1].split(",")}
    assert set(documented) == set(chaos.PRESETS), (
        f"README chaos table out of sync with core/chaos.py PRESETS: "
        f"documented={sorted(documented)} "
        f"registered={sorted(chaos.PRESETS)}")
    for name, build in chaos.PRESETS.items():
        actual = {ev.kind for ev in build(4, 2, 20)}
        assert documented[name] == actual, (
            f"preset {name!r}: README documents faults "
            f"{sorted(documented[name])}, builder schedules "
            f"{sorted(actual)}")


def test_readme_quickstart_flags_exist_in_train_cli():
    """Every flag the README documents is a real train.py option (the
    full --dry-run execution runs in benchmarks/run.py --quick)."""
    from benchmarks import docs_smoke
    from repro.launch import train as train_mod

    commands = docs_smoke.quickstart_commands(README)
    assert commands, "README quickstart documents no train commands"
    # collect the parser's option strings without running it
    import argparse
    real_flags = set()
    orig = argparse.ArgumentParser.parse_args
    try:
        argparse.ArgumentParser.parse_args = lambda self, *a, **k: (
            real_flags.update(o for action in self._actions
                              for o in action.option_strings),
            sys.exit(0))[1]
        with pytest.raises(SystemExit):
            train_mod.main()
    finally:
        argparse.ArgumentParser.parse_args = orig
    for args in commands:
        for tok in args:
            if tok.startswith("--"):
                assert tok in real_flags, (
                    f"README documents unknown flag {tok}; "
                    f"known: {sorted(real_flags)}")


def test_readme_pipeline_quickstart_documents_real_requirements():
    """The README must document a runnable --pipeline-stages command,
    and the requirements it demonstrates must be REAL: the documented
    flag set carries --no-scan-layers and --accum >= stages, and
    HetConfig.validate actually rejects a config missing them."""
    from benchmarks import docs_smoke

    commands = docs_smoke.quickstart_commands(README)
    pipe_cmds = [a for a in commands if "--pipeline-stages" in a]
    assert pipe_cmds, ("README quickstart documents no "
                       "--pipeline-stages command")
    for args in pipe_cmds:
        stages = int(args[args.index("--pipeline-stages") + 1])
        assert stages > 1, args
        assert "--no-scan-layers" in args, (
            "documented pipeline command must carry --no-scan-layers "
            "(the per-stage VJP segments need the unrolled stack)")
        assert "--accum" in args, args
        accum = int(args[args.index("--accum") + 1])
        assert accum >= stages, (
            f"documented pipeline command has --accum {accum} < "
            f"--pipeline-stages {stages}")
    # the documented requirements are enforced, not decorative
    with pytest.raises(ValueError, match="accum_steps"):
        HetConfig(pipeline_stages=2, accum_steps=1).validate()
    with pytest.raises(ValueError, match="overlap"):
        HetConfig(pipeline_stages=2, accum_steps=2, overlap="buckets",
                  grad_reduction="bucketed_allreduce",
                  bucket_mb=1.0).validate()


def test_readme_serve_flag_table_matches_serve_cli(readme_tables):
    """The serving section's flag table lists EXACTLY the serve
    driver's argparse options — a flag added/renamed in
    launch/serve.py without a README row (or vice versa) fails."""
    from repro.launch import serve as serve_mod

    table = _find_table(readme_tables, "flag", "default", "meaning")
    documented = {row[0].strip("`") for row in table[1:]}
    import argparse
    real_flags = set()
    orig = argparse.ArgumentParser.parse_args
    try:
        argparse.ArgumentParser.parse_args = lambda self, *a, **k: (
            real_flags.update(o for action in self._actions
                              for o in action.option_strings),
            sys.exit(0))[1]
        with pytest.raises(SystemExit):
            serve_mod.main()
    finally:
        argparse.ArgumentParser.parse_args = orig
    real_flags -= {"-h", "--help"}
    assert documented == real_flags, (
        f"README serve flag table out of sync with launch/serve.py:\n"
        f"documented-only={sorted(documented - real_flags)}\n"
        f"parser-only={sorted(real_flags - documented)}")


def test_attention_impl_knob_is_pinned_end_to_end():
    """``attention_impl`` (PR 9): the serve CLI's choices are EXACTLY
    ``ATTENTION_IMPLS``, ModelConfig rejects unknown values with a
    message naming the knob, and both docs surfaces — the README
    serving section and architecture.md §serving engine — document the
    flag and its loud interpret-mode fallback."""
    from repro.launch import serve as serve_mod

    import argparse
    choices = {}
    orig = argparse.ArgumentParser.parse_args
    try:
        argparse.ArgumentParser.parse_args = lambda self, *a, **k: (
            choices.update({o: action.choices
                            for action in self._actions
                            for o in action.option_strings}),
            sys.exit(0))[1]
        with pytest.raises(SystemExit):
            serve_mod.main()
    finally:
        argparse.ArgumentParser.parse_args = orig
    assert list(choices["--attention-impl"]) == list(
        cfgs.ATTENTION_IMPLS), (
        f"serve --attention-impl choices {choices['--attention-impl']} "
        f"!= configs/base.py ATTENTION_IMPLS {cfgs.ATTENTION_IMPLS}")

    with pytest.raises(ValueError, match="attention_impl"):
        dataclasses.replace(cfgs.smoke_config("olmo-1b"),
                            attention_impl="bogus")

    with open(README) as fh:
        readme = fh.read()
    assert "--attention-impl" in readme
    arch_md = os.path.join(REPO, "docs", "architecture.md")
    with open(arch_md) as fh:
        arch = fh.read()
    for doc, text in (("README.md", readme),
                      ("docs/architecture.md", arch)):
        assert "attention_impl" in text and "interpret" in text, (
            f"{doc} must document the attention_impl knob and its "
            f"loud interpret-mode fallback")


def test_label_smoothing_is_wired_through_the_train_step():
    """TrainConfig.label_smoothing is a LIVE knob (the docstring says
    so): it must reach the CE loss both via loss_fn and via
    build_train_step."""
    from repro import compat
    from repro.configs.base import OptimizerConfig, ShapeConfig
    from repro.launch import steps
    from repro.models.model import build_model

    model_cfg = dataclasses.replace(cfgs.smoke_config("olmo-1b"),
                                    compute_dtype="float32")
    model = build_model(model_cfg)
    params = jax.jit(model.init_params)(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    batch = {
        "inputs": jnp.asarray(
            rng.integers(0, model_cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, model_cfg.vocab_size, (2, 16)), jnp.int32),
        "weights": jnp.ones((2, 16), jnp.float32),
    }
    o0, _, _ = model.loss_fn(params, batch)
    o1, _, _ = model.loss_fn(params, batch, label_smoothing=0.2)
    assert float(o0) != float(o1), "label_smoothing kwarg is dead"

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 16, 2, "train")

    def one_loss(smoothing):
        tcfg = TrainConfig(model=model_cfg, shape=shape,
                           het=HetConfig(),
                           optimizer=OptimizerConfig(grad_clip=0.0),
                           label_smoothing=smoothing)
        with compat.set_mesh(mesh):
            state = steps.init_train_state(model, tcfg, mesh,
                                           jax.random.PRNGKey(0))
            step = steps.build_train_step(model, tcfg, mesh)
            _, met = step(state, batch)
        return float(met["loss"])

    assert one_loss(0.0) != one_loss(0.2), (
        "TrainConfig.label_smoothing does not reach the train step")
    with pytest.raises(ValueError, match="label_smoothing"):
        steps.validate_train_config(
            model, TrainConfig(model=model_cfg, label_smoothing=1.5),
            mesh)


def test_checkpoint_restore_logs_overlap_mode_mismatch(tmp_path,
                                                       caplog):
    """The checkpoint records which overlap mode wrote it, and restore
    LOGS a mismatch instead of silently adapting."""
    from repro.checkpoint import repack
    from repro.checkpoint.checkpoint import CheckpointManager

    state = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    fmt = {"version": repack.FORMAT_VERSION, "state": "pytree",
           "packed_fields": [], "layout": None, "overlap": "buckets"}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state, meta={"format": fmt}, block=True)

    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        restored, meta = mgr.restore(state,
                                     expected_overlap="backward")
    np.testing.assert_array_equal(restored["w"], state["w"])
    assert meta["format"]["overlap"] == "buckets"
    assert any("overlap='buckets'" in r.message and
               "overlap='backward'" in r.message
               for r in caplog.records), caplog.records

    caplog.clear()
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        mgr.restore(state, expected_overlap="buckets")
    assert not caplog.records              # matching mode: no warning
