"""Per-architecture smoke tests (reduced same-family configs, CPU) and
decode-vs-teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import transformer as tr
from repro.models.model import build_model, count_params_analytic

ARCHS = cfgbase.list_archs()


def _inputs_for(cfg, key, b, s):
    if cfg.frontend == "token":
        return jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return jax.random.normal(key, (b, s, cfg.d_model))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one gradient step on CPU: output shapes, no NaNs,
    loss decreases after an SGD nudge."""
    cfg = cfgbase.smoke_config(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    b, s = 2, 24
    batch = {"inputs": _inputs_for(cfg, key, b, s),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "weights": jnp.ones((b, s))}

    def loss(p):
        o, w, _ = m.loss_fn(p, batch)
        return o / w

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0)), arch
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32))), arch
    # small step: MoE routing flips make the loss discontinuous, so the
    # descent check must stay inside the local linear regime
    lr = 0.1 if cfg.moe.enabled else 0.5
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
    l1 = loss(params2)
    assert float(l1) < float(l0), f"{arch}: {l0} -> {l1}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_logits_shape(arch):
    cfg = cfgbase.smoke_config(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init_params(key)
    logits = m.logits_fn(params, _inputs_for(cfg, key, 2, 16))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode-with-cache logits match prefilling the longer sequence."""
    cfg = cfgbase.smoke_config(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init_params(key)
    b, s = 2, 16
    full = _inputs_for(cfg, key, b, s + 2)
    ref_logits, _ = m.prefill(params, full)
    logits, cache = m.prefill(params, full[:, :s], max_len=s + 2)
    for i in range(2):
        pos = s + i
        nxt = full[:, pos] if cfg.frontend == "token" else full[:, pos, :]
        logits, cache = m.decode(params, nxt, cache, jnp.int32(pos))
    err = float(np.max(np.abs(np.asarray(logits, np.float32) -
                              np.asarray(ref_logits, np.float32))))
    assert err < 6e-2, f"{arch}: decode err {err}"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_resolves_and_counts(arch):
    """Full (assigned) configs instantiate analytically — no allocation.
    Param counts must be within 40% of the arch's nameplate size."""
    cfg = cfgbase.resolve(arch)
    n = count_params_analytic(cfg)
    nameplate = {
        "olmo-1b": 1.2e9, "tinyllama-1.1b": 1.1e9, "glm4-9b": 9e9,
        "phi4-mini-3.8b": 3.8e9, "chameleon-34b": 34e9,
        "arctic-480b": 480e9, "deepseek-v2-236b": 236e9,
        "zamba2-2.7b": 2.7e9, "musicgen-large": 1.5e9,
        "xlstm-125m": 125e6,
    }[arch]
    assert 0.6 * nameplate < n < 1.7 * nameplate, f"{arch}: {n:,}"
    if cfg.moe.enabled:
        na = count_params_analytic(cfg, active_only=True)
        assert na < n / 4, "MoE active params should be << total"


def test_stack_plans():
    assert tr.stack_plan(cfgbase.resolve("olmo-1b")) == "uniform"
    assert tr.stack_plan(cfgbase.resolve("arctic-480b")) == "uniform"
    assert tr.stack_plan(cfgbase.resolve("zamba2-2.7b")) == "zamba"
    assert tr.stack_plan(cfgbase.resolve("xlstm-125m")) == "xlstm"


def test_shape_applicability_matrix():
    """The 40-cell grid: long_500k runs only for sub-quadratic archs."""
    live, skipped = 0, 0
    for arch in ARCHS:
        cfg = cfgbase.resolve(arch)
        for shape in cfgbase.SHAPES.values():
            ok, why = cfgbase.shape_applicable(cfg, shape)
            if ok:
                live += 1
            else:
                skipped += 1
                assert shape.name == "long_500k"
                assert not cfg.sub_quadratic
    assert live + skipped == 40
    assert skipped == 8              # the 8 pure full-attention archs
    assert cfgbase.resolve("zamba2-2.7b").sub_quadratic
    assert cfgbase.resolve("xlstm-125m").sub_quadratic


def test_weighted_loss_ignores_dummy_rows():
    """Model-level M3: appending weight-0 rows never changes the loss."""
    cfg = cfgbase.smoke_config("olmo-1b")
    m = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init_params(key)
    b, s = 3, 12
    inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    o1, w1, _ = m.loss_fn(params, {"inputs": inputs, "labels": labels,
                                   "weights": jnp.ones((b, s))})
    inputs2 = jnp.concatenate([inputs, inputs[:1]], 0)
    labels2 = jnp.concatenate([labels, labels[:1]], 0)
    weights2 = jnp.concatenate([jnp.ones((b, s)), jnp.zeros((1, s))], 0)
    o2, w2, _ = m.loss_fn(params, {"inputs": inputs2, "labels": labels2,
                                   "weights": weights2})
    assert abs(float(o1 / w1) - float(o2 / w2)) < 1e-5
