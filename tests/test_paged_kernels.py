"""Property-based parity for the paged decode kernels (PR 9 tentpole).

The paged Pallas kernels gather KV blocks through the block table
INSIDE the kernel; the reference path materializes the window in HBM
first (``.at[tables].get(mode="fill", fill_value=0)``). The acceptance
bar is asymmetric by design:

  * GQA flash decode — fp32-BITWISE equal to the reference across
    ragged kv_lens / block sizes / head counts / GQA group sizes (the
    kernel replicates ``ref.mha_dense``'s exact contraction shapes; a
    same-math different-shape einsum drifts by 1 ulp on XLA CPU).
  * absorbed-MLA decode — within compute-dtype tolerance (the kernel is
    a streaming online-softmax, a different — better — reduction order
    than the dense reference).

Everything runs in interpret mode (``pallas_interpret`` marker) so the
sweep executes on the compat CPU jaxlib in CI.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.mla_decode import ops as mla_ops

pytestmark = pytest.mark.pallas_interpret


def _ragged_tables(rng, batch, mb, bs, n_pool):
    """Prefix-mapped block tables + ragged effective kv_lens.

    Each sequence maps just enough distinct pool blocks for its depth;
    the rest of its table row is NULL (== n_pool). Depths deliberately
    hit block boundaries (1, bs, s_g) as well as interiors.
    """
    s_g = mb * bs
    kv_lens = np.asarray(
        [int(rng.integers(1, s_g + 1)) for _ in range(batch)], np.int32)
    perm = rng.permutation(n_pool)
    tables = np.full((batch, mb), n_pool, np.int32)
    used = 0
    for i in range(batch):
        nb = -(-int(kv_lens[i]) // bs)
        tables[i, :nb] = perm[used:used + nb]
        used += nb
    return jnp.asarray(tables), jnp.asarray(kv_lens)


@settings(max_examples=25, deadline=None)
@given(bs=st.sampled_from([2, 4, 8]),
       mb=st.integers(min_value=1, max_value=4),
       hkv=st.sampled_from([1, 2, 3]),
       q_per_kv=st.sampled_from([1, 2, 4]),
       d=st.sampled_from([4, 8, 16]),
       lens_seed=st.integers(min_value=0, max_value=2 ** 16))
def test_gqa_paged_pallas_bitwise_vs_reference(pallas_interpret, bs, mb,
                                               hkv, q_per_kv, d,
                                               lens_seed):
    rng = np.random.default_rng((bs, mb, hkv, q_per_kv, d, lens_seed))
    batch = int(rng.integers(1, 5))
    h = hkv * q_per_kv
    n_pool = batch * mb + 2           # spare blocks stay unmapped
    tables, kv_lens = _ragged_tables(rng, batch, mb, bs, n_pool)
    q = jnp.asarray(rng.standard_normal((batch, 1, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((n_pool, bs, hkv, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_pool, bs, hkv, d)),
                         jnp.float32)
    out_ref = attn_ops.flash_decode_paged(
        q, k_pool, v_pool, tables, kv_lens, impl="reference")
    out_pal = attn_ops.flash_decode_paged(
        q, k_pool, v_pool, tables, kv_lens, impl="pallas",
        interpret=pallas_interpret)
    assert np.array_equal(np.asarray(out_ref), np.asarray(out_pal)), (
        f"paged GQA pallas decode not fp32-bitwise vs reference "
        f"(max err {np.abs(np.asarray(out_ref) - np.asarray(out_pal)).max()}"
        f", shapes bs={bs} mb={mb} hkv={hkv} qpk={q_per_kv} d={d} "
        f"kv_lens={np.asarray(kv_lens).tolist()})")


@settings(max_examples=20, deadline=None)
@given(bs=st.sampled_from([2, 4, 8]),
       mb=st.integers(min_value=1, max_value=4),
       h=st.sampled_from([2, 4, 8]),
       r=st.sampled_from([8, 16]),
       dr=st.sampled_from([4, 8]),
       lens_seed=st.integers(min_value=0, max_value=2 ** 16))
def test_mla_paged_pallas_tolerance_vs_reference(pallas_interpret, bs, mb,
                                                 h, r, dr, lens_seed):
    rng = np.random.default_rng((bs, mb, h, r, dr, lens_seed))
    batch = int(rng.integers(1, 4))
    n_pool = batch * mb + 2
    tables, kv_lens = _ragged_tables(rng, batch, mb, bs, n_pool)
    q_abs = jnp.asarray(rng.standard_normal((batch, h, r)), jnp.float32)
    q_r = jnp.asarray(rng.standard_normal((batch, h, dr)), jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((n_pool, bs, r)), jnp.float32)
    kr = jnp.asarray(rng.standard_normal((n_pool, bs, dr)), jnp.float32)
    scale = (r + dr) ** -0.5
    out_ref = mla_ops.mla_decode_paged_attention(
        q_abs, q_r, ckv, kr, tables, kv_lens, scale, impl="reference")
    out_pal = mla_ops.mla_decode_paged_attention(
        q_abs, q_r, ckv, kr, tables, kv_lens, scale, impl="pallas",
        interpret=pallas_interpret)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal),
                               atol=2e-5)


def test_gqa_paged_null_sentinel_fully_masked(pallas_interpret):
    """An inactive slot (all-NULL table, kv_len 1) must match the
    reference's zero-fill gather bitwise — the clamped DMA source block
    holds real data the kernel is required to zero out."""
    rng = np.random.default_rng(7)
    bs, mb, hkv, d, n_pool = 4, 3, 2, 8, 6
    k_pool = jnp.asarray(rng.standard_normal((n_pool, bs, hkv, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_pool, bs, hkv, d)),
                         jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, d)), jnp.float32)
    tables = jnp.asarray(
        [[0, 1, n_pool],              # active: 2 mapped blocks
         [n_pool, n_pool, n_pool]],   # inactive slot: all NULL
        jnp.int32)
    kv_lens = jnp.asarray([2 * bs, 1], jnp.int32)
    out_ref = attn_ops.flash_decode_paged(
        q, k_pool, v_pool, tables, kv_lens, impl="reference")
    out_pal = attn_ops.flash_decode_paged(
        q, k_pool, v_pool, tables, kv_lens, impl="pallas",
        interpret=pallas_interpret)
    assert np.array_equal(np.asarray(out_ref), np.asarray(out_pal))


def test_model_level_paged_decode_bitwise_fp32(pallas_interpret):
    """Full-model parity: decode_paged logits with attention_impl=
    'pallas' are fp32-bitwise (GQA) / tolerance-equal (MLA) vs the
    reference engine path, through scatter + attention + unembed."""
    from repro.configs import base as cfgbase
    from repro.models import kvcache as kvc
    from repro.models.model import build_model

    for arch, bitwise in [("olmo-1b", True), ("deepseek-v2-236b", False)]:
        cfg = dataclasses.replace(
            cfgbase.smoke_config(arch), param_dtype="float32",
            compute_dtype="float32", remat="none")
        model_r = build_model(cfg)
        model_p = build_model(
            dataclasses.replace(cfg, attention_impl="pallas"))
        layout = kvc.PagedLayout(block_size=4, num_blocks=24,
                                 max_blocks_per_seq=4)
        params = jax.jit(model_r.init_params)(jax.random.PRNGKey(0))
        kv_lens = jnp.asarray([5, 9, 12], jnp.int32)
        tables_np = np.full((3, 4), layout.null_block, np.int32)
        blk = 0
        for i in range(3):
            nb = layout.blocks_for(int(kv_lens[i]) + 1)
            tables_np[i, :nb] = np.arange(blk, blk + nb)
            blk += nb
        tables = jnp.asarray(tables_np)
        key = jax.random.PRNGKey(1)
        cache_r, cache_p = {}, {}
        for name, leaf in model_r.init_paged_cache(layout).items():
            key, k2 = jax.random.split(key)
            content = jax.random.normal(k2, leaf.shape, leaf.dtype)
            cache_r[name], cache_p[name] = content, content
        toks = jnp.asarray([3, 1, 4], jnp.int32)
        lr, _ = model_r.decode_paged(params, toks, cache_r, tables,
                                     kv_lens)
        lp, _ = model_p.decode_paged(params, toks, cache_p, tables,
                                     kv_lens)
        if bitwise:
            assert np.array_equal(np.asarray(lr), np.asarray(lp)), (
                f"{arch}: pallas decode logits not fp32-bitwise vs "
                f"reference")
        else:
            np.testing.assert_allclose(np.asarray(lr), np.asarray(lp),
                                       atol=1e-4)


def test_unknown_impl_raises():
    z4 = jnp.zeros((1, 1, 2, 4))
    pool = jnp.zeros((2, 2, 2, 4))
    tbl = jnp.zeros((1, 1), jnp.int32)
    lens = jnp.ones((1,), jnp.int32)
    with pytest.raises(ValueError, match="unknown attention impl"):
        attn_ops.flash_decode_paged(z4, pool, pool, tbl, lens,
                                    impl="nope")
    with pytest.raises(ValueError, match="unknown mla decode impl"):
        mla_ops.mla_decode_paged_attention(
            jnp.zeros((1, 2, 8)), jnp.zeros((1, 2, 4)),
            jnp.zeros((2, 2, 8)), jnp.zeros((2, 2, 4)), tbl, lens, 0.1,
            impl="nope")
