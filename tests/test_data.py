"""Data pipeline: shard format, lazy dataset, het sampler, prefetch."""
import threading

import numpy as np
import pytest

from repro.core import capacity
from repro.data import loader, sampler, shards, synthetic
from repro.data.dataset import ShardedDataset


@pytest.fixture()
def corpus(tmp_path):
    return synthetic.build_synthetic_corpus(
        str(tmp_path / "corpus"), num_seqs=100, seq_len=32, vocab=64,
        rows_per_shard=16, seed=0)


def test_shard_roundtrip(tmp_path, corpus):
    assert len(corpus) == 100
    assert corpus.num_shards == 7
    assert corpus.locate(0) == (0, 0)
    assert corpus.locate(16) == (1, 0)
    assert corpus.locate(99) == (6, 3)
    with pytest.raises(IndexError):
        corpus.locate(100)


def test_dataset_lazy_lru(corpus):
    ds = ShardedDataset(corpus, lru_shards=2)
    r = ds[17]
    assert set(r) == {"inputs", "labels"}
    assert r["inputs"].shape == (32,)
    # labels are inputs shifted by one (LM convention)
    full = synthetic.zipf_bigram_tokens(100, 32, 64, seed=0)
    np.testing.assert_array_equal(r["inputs"], full[17, :-1])
    np.testing.assert_array_equal(r["labels"], full[17, 1:])
    # touch many shards; LRU stays bounded
    for i in range(0, 100, 7):
        ds[i]
    assert len(ds._cache) <= 2 * len(corpus.fields)


def test_gather_groups_by_shard(corpus):
    ds = ShardedDataset(corpus)
    idx = [99, 0, 17, 18, 50]
    batch = ds.gather(idx)
    for j, i in enumerate(idx):
        np.testing.assert_array_equal(batch["inputs"][j], ds[i]["inputs"])


def test_epoch_determinism_and_coverage(corpus):
    ds = ShardedDataset(corpus)
    plan = capacity.plan_capacities(24, [2, 1, 1])
    smp = sampler.HetSampler(ds, plan, seed=7)
    # determinism across "hosts"
    a = [b_["inputs"].copy() for b_ in smp.iter_epoch(3)]
    b = [b_["inputs"].copy() for b_ in smp.iter_epoch(3)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # different epochs shuffle differently
    c = list(smp.iter_epoch(4))
    assert not np.array_equal(a[0], c[0]["inputs"])
    # every real token consumed exactly once per epoch
    total_w = sum(float(x["weights"].sum()) for x in smp.iter_epoch(0))
    assert total_w == 100 * 32


def test_max_tokens_batching():
    lengths = np.array([10, 20, 30, 40, 5, 5, 5])
    batches = sampler.plan_epoch_batches(
        7, seed=0, epoch=0, max_tokens=45, lengths=lengths)
    seen = np.concatenate([b.indices for b in batches])
    assert sorted(seen.tolist()) == list(range(7))
    for b in batches[:-1]:
        assert lengths[b.indices].sum() <= 45


def test_prefetch_loader_matches_sync(corpus):
    ds = ShardedDataset(corpus)
    plan = capacity.plan_capacities(20, [1, 1])
    smp = sampler.HetSampler(ds, plan, seed=1)
    sync = [b["labels"].copy() for b in smp.iter_epoch(0)]
    ld = loader.PrefetchLoader(smp, depth=3)
    asyncb = [b["labels"].copy() for b in ld.iter_epoch(0)]
    assert len(sync) == len(asyncb)
    for x, y in zip(sync, asyncb):
        np.testing.assert_array_equal(x, y)


def test_prefetch_surfaces_producer_errors(corpus):
    ds = ShardedDataset(corpus)
    plan = capacity.plan_capacities(20, [1, 1])
    smp = sampler.HetSampler(ds, plan, seed=1)

    def boom(entry):
        raise RuntimeError("producer exploded")

    smp.pack = boom
    ld = loader.PrefetchLoader(smp, depth=1)
    with pytest.raises(RuntimeError, match="producer exploded"):
        list(ld.iter_epoch(0))


def test_varlen_weights(tmp_path):
    idx = synthetic.build_synthetic_corpus(
        str(tmp_path / "varlen"), num_seqs=40, seq_len=16, vocab=32,
        rows_per_shard=8, seed=0, varlen=True)
    ds = ShardedDataset(idx)
    plan = capacity.plan_capacities(8, [1, 1])
    smp = sampler.HetSampler(ds, plan, seed=0)
    batch = next(iter(smp.iter_epoch(0)))
    # padding inside real rows carries weight 0 (paper: token weighting)
    w = batch["weights"]
    assert w.max() == 1.0
    assert (w.sum(axis=1) <= 16).all()
    assert (w.sum(axis=1) > 0).any()
