"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus custom-VJP gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cross_entropy import ref as ce_ref
from repro.kernels.cross_entropy.cross_entropy import cross_entropy_pallas
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.mlstm_scan import ref as ml_ref
from repro.kernels.mlstm_scan.mlstm_scan import mlstm_scan_pallas
from repro.kernels.quantize import ref as q_ref
from repro.kernels.quantize.quantize import quantize_int8_pallas
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas

# every test here executes pallas_call with interpret=True; skip the
# whole module (with the probe's reason) where that cannot run
pytestmark = pytest.mark.pallas_interpret


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,sq,skv,h,hkv,d,causal,off", [
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 200, 200, 4, 4, 64, True, 0),       # non-multiple of block
    (2, 1, 256, 8, 2, 128, True, 255),      # decode-style single query
    (1, 64, 320, 4, 1, 32, False, 0),       # MQA, non-causal
    (1, 96, 96, 6, 3, 16, True, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_dense(b, sq, skv, h, hkv, d, causal,
                                         off, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    out_p = flash_attention_pallas(q, k, v, causal=causal, q_offset=off,
                                   interpret=True)
    out_r = fa_ref.mha_dense(q, k, v, causal=causal, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)


def test_flash_chunked_matches_dense_with_kv_len():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 8, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    kv_len = jnp.array([17, 40], jnp.int32)
    out_c = fa_ref.mha_chunked(q, k, v, causal=False, kv_len=kv_len,
                               chunk_size=16)
    out_d = fa_ref.mha_dense(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               atol=2e-5)


def test_flash_custom_vjp_matches_dense_grad():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 16))
    k = jax.random.normal(ks[1], (2, 48, 2, 16))
    v = jax.random.normal(ks[2], (2, 48, 2, 16))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(fa_ref.mha_dense(q, k, v)))

    def f_new(q, k, v):
        return jnp.sum(jnp.sin(fa_ref.mha_chunked(q, k, v, chunk_size=16)))

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# --------------------------------------------------------------------------
# cross entropy
# --------------------------------------------------------------------------


@pytest.mark.parametrize("t,d,v,ls,cap", [
    (64, 32, 100, 0.0, 0.0),
    (300, 64, 1500, 0.1, 0.0),
    (128, 48, 2048, 0.0, 30.0),
    (17, 16, 130, 0.1, 0.0),                # odd sizes
])
def test_ce_pallas_vs_dense(t, d, v, ls, cap):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    h = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    lab = jax.random.randint(ks[2], (t,), 0, v)
    wt = (jax.random.uniform(ks[3], (t,)) > 0.2).astype(jnp.float32)
    lp, wp = cross_entropy_pallas(h, w, lab, wt, label_smoothing=ls,
                                  logit_softcap=cap, interpret=True)
    lr, wr = ce_ref.ce_dense(h, w, lab, wt, label_smoothing=ls,
                             logit_softcap=cap)
    assert abs(float(lp) - float(lr)) / max(abs(float(lr)), 1.0) < 1e-5
    assert abs(float(wp) - float(wr)) < 1e-5


def test_ce_chunked_vjp_matches_dense_grad():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    h = jax.random.normal(ks[0], (100, 16))
    w = jax.random.normal(ks[1], (16, 512)) * 0.1
    lab = jax.random.randint(ks[2], (100,), 0, 512)
    wt = (jax.random.uniform(ks[3], (100,)) > 0.3).astype(jnp.float32)

    def f(fn):
        def inner(h, w):
            l, ws = fn(h, w, lab, wt, label_smoothing=0.1)
            return l / ws
        return inner

    g_ref = jax.grad(f(ce_ref.ce_dense), argnums=(0, 1))(h, w)
    g_new = jax.grad(
        f(lambda *a, **k: ce_ref.ce_chunked(*a, chunk_size=32, **k)),
        argnums=(0, 1))(h, w)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_ce_dummy_tokens_do_not_contribute():
    """Weight-0 (dummy) tokens must not change loss or gradient (M3)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    h = jax.random.normal(ks[0], (20, 8))
    w = jax.random.normal(ks[1], (8, 64)) * 0.1
    lab = jax.random.randint(ks[2], (20,), 0, 64)
    wt_full = jnp.ones((20,)).at[10:].set(0.0)
    l1, s1 = ce_ref.ce_chunked(h, w, lab, wt_full, chunk_size=8)
    l2, s2 = ce_ref.ce_chunked(h[:10], w, lab[:10], jnp.ones((10,)),
                               chunk_size=8)
    assert abs(float(l1) - float(l2)) < 1e-4
    assert float(s1) == float(s2) == 10.0


# --------------------------------------------------------------------------
# SSD scan (Mamba2)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 256, 8, 32, 2, 64, 128),
    (1, 100, 4, 16, 1, 32, 64),             # padding path
    (2, 64, 6, 8, 3, 16, 32),               # groups
])
def test_ssd_pallas_vs_sequential(b, s, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    D = jax.random.normal(ks[5], (h,))
    yp, fp = ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk_size=chunk,
                             interpret=True)
    yr, fr = ssd_ref.ssd_sequential(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(fr), atol=2e-3)


def test_ssd_chunked_matches_sequential_and_decode():
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    b, s, h, p, n = 1, 33, 2, 8, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
    y_c, f_c = ssd_ref.ssd_chunked(x, dt, A, Bm, Cm, chunk_size=16)
    y_s, f_s = ssd_ref.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=1e-4)
    # step-by-step decode equals the scan
    state = jnp.zeros((b, h, p, n))
    for t in range(s):
        yt, state = ssd_ref.ssd_decode_step(
            state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(yt), np.asarray(y_s[:, t]),
                                   atol=1e-4)


# --------------------------------------------------------------------------
# mLSTM scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (2, 128, 4, 32, 32, 64),
    (1, 100, 2, 16, 24, 32),
    (2, 64, 3, 8, 8, 16),
])
def test_mlstm_pallas_vs_sequential(b, s, h, dk, dv, chunk):
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ip = jax.random.normal(ks[3], (b, s, h)) * 2
    fp_ = jax.random.normal(ks[4], (b, s, h)) * 2 + 2
    yp, _ = mlstm_scan_pallas(q, k, v, ip, fp_, chunk_size=chunk,
                              interpret=True)
    yr, _ = ml_ref.mlstm_sequential(q, k, v, ip, fp_)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-3)


def test_mlstm_decode_step_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, s, h, dk = 2, 17, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dk))
    ip = jax.random.normal(ks[3], (b, s, h))
    fp_ = jax.random.normal(ks[4], (b, s, h)) + 2
    y_ref, _ = ml_ref.mlstm_sequential(q, k, v, ip, fp_)
    state = (jnp.zeros((b, h, dk, dk)), jnp.zeros((b, h, dk)),
             jnp.full((b, h), -1e30))
    for t in range(s):
        yt, state = ml_ref.mlstm_decode_step(
            state, q[:, t], k[:, t], v[:, t], ip[:, t], fp_[:, t])
        np.testing.assert_allclose(np.asarray(yt), np.asarray(y_ref[:, t]),
                                   atol=1e-4)


# --------------------------------------------------------------------------
# quantize
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape,bs", [((1000,), 256), ((64, 70), 128),
                                      ((3, 5, 7), 64)])
def test_quantize_pallas_vs_ref(shape, bs):
    x = jax.random.normal(jax.random.PRNGKey(10), shape) * 3
    qp, sp = quantize_int8_pallas(x, block_size=bs, interpret=True)
    qr, sr = q_ref.quantize_int8(x, block_size=bs)
    assert np.array_equal(np.asarray(qp), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-6)
    xd = q_ref.dequantize_int8(qp, sp, shape, bs)
    assert float(jnp.max(jnp.abs(xd - x))) < 3 * float(jnp.max(sp))


def test_quantize_stochastic_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(11), (200000,))
    q, s = q_ref.quantize_int8(x, block_size=256,
                               key=jax.random.PRNGKey(12))
    xd = q_ref.dequantize_int8(q, s, x.shape, 256)
    assert abs(float(jnp.mean(xd - x))) < 1e-4


@pytest.mark.parametrize("ranks,blocks,bs", [(2, 8, 256), (4, 300, 128),
                                             (3, 5, 64)])
def test_dequant_accum_pallas_vs_ref(ranks, blocks, bs):
    """Fused receive-side dequant+accumulate == per-rank dequant sum."""
    from repro.kernels.quantize.quantize import dequant_accum_pallas
    ks = jax.random.split(jax.random.PRNGKey(13), 2)
    q = jax.random.randint(ks[0], (ranks, blocks, bs), -127,
                           128).astype(jnp.int8)
    s = jax.random.uniform(ks[1], (ranks, blocks)) * 0.1
    out_p = dequant_accum_pallas(q, s, interpret=True)
    out_r = q_ref.dequant_accum(q, s)
    # unrolled-accumulate vs einsum reassociate: fp noise only
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)
    # the oracle itself == explicit per-rank dequantize-then-add
    manual = sum(np.asarray(q[r], np.float32) *
                 np.asarray(s[r])[:, None] for r in range(ranks))
    np.testing.assert_allclose(np.asarray(out_r), manual, rtol=1e-4,
                               atol=1e-5)


def test_bucketed_quantize_single_fused_call_roundtrip():
    """The bucket-stack view (nb, ranks, shard) quantizes in ONE call
    and dequantizes back within int8 tolerance."""
    x = jax.random.normal(jax.random.PRNGKey(14), (3, 2, 512)) * 2
    q, s = q_ref.quantize_int8(x, block_size=256)
    assert q.shape == (3 * 2 * 512 // 256, 256)
    xd = q_ref.dequantize_int8(q, s, x.shape, 256)
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.51


# --------------------------------------------------------------------------
# MLA flash decode
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,r,dr,s,chunk", [
    (2, 8, 64, 16, 256, 64),
    (1, 4, 32, 8, 100, 32),                 # non-multiple of chunk
    (2, 16, 128, 32, 512, 128),
])
def test_mla_decode_pallas_vs_dense(b, h, r, dr, s, chunk):
    from repro.kernels.mla_decode import ref as md_ref
    from repro.kernels.mla_decode.mla_decode import mla_decode_pallas
    ks = jax.random.split(jax.random.PRNGKey(20), 5)
    q_abs = jax.random.normal(ks[0], (b, h, r)) * 0.3
    q_r = jax.random.normal(ks[1], (b, h, dr)) * 0.3
    ckv = jax.random.normal(ks[2], (b, s, r)) * 0.3
    kr = jax.random.normal(ks[3], (b, s, dr)) * 0.3
    kv_len = jax.random.randint(ks[4], (b,), s // 2, s + 1)
    scale = (r + dr) ** -0.5
    out_p = mla_decode_pallas(q_abs, q_r, ckv, kr, kv_len, scale,
                              chunk=chunk, interpret=True)
    out_r = md_ref.mla_decode_dense(q_abs, q_r, ckv, kr, kv_len, scale)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=2e-5)
